//! Table runners: regenerate every paper table/figure from this stack.

use std::sync::Arc;

use super::paper;
use super::workloads::{binary_workload, multiclass_workload};
use crate::backend::{NativeBackend, Solver, SvmBackend, XlaBackend};
use crate::coordinator::{train_multiclass, Partition, TrainConfig};
use crate::error::Result;
use crate::metrics::bench::{BenchConfig, BenchResult};
use crate::metrics::table::Table;

/// Repeat-and-summarize a training closure (median over samples).
fn time_train(name: &str, cfg: &BenchConfig, mut f: impl FnMut()) -> BenchResult {
    crate::metrics::bench::bench(name, cfg, &mut f)
}

/// One Table III / Fig 6 row.
#[derive(Debug, Clone)]
pub struct Table3Row {
    pub per_class: usize,
    pub cuda_secs: f64,
    pub tf_secs: f64,
    pub speedup: f64,
    pub smo_iters: usize,
}

/// Table III: Pavia binary training, CUDA-analog (chunked device SMO) vs
/// TF-analog (fixed-step device GD), sweep over samples/class.
pub fn run_table3(
    be: &XlaBackend,
    sweep: &[usize],
    cfg: &BenchConfig,
    seed: u64,
) -> Result<(Table, Vec<Table3Row>)> {
    let mut table = Table::new(
        "Table III — binary training time, Pavia (CUDA-analog vs TF-analog)",
        &["#samples/#classes", "SMO-device (s)", "GD-device (s)", "speedup", "paper"],
    );
    let mut rows = Vec::new();
    for (i, &per_class) in sweep.iter().enumerate() {
        let w = binary_workload("pavia", per_class, seed);
        let prob = w.problem();

        let mut iters = 0usize;
        let cuda = time_train(&format!("smo-{per_class}"), cfg, || {
            let (_, st) = be.train_binary(&prob, &w.params, Solver::Smo).unwrap();
            iters = st.iters;
        });
        let tf = time_train(&format!("gd-{per_class}"), cfg, || {
            be.train_binary(&prob, &w.params, Solver::Gd).unwrap();
        });

        let row = Table3Row {
            per_class,
            cuda_secs: cuda.summary.median,
            tf_secs: tf.summary.median,
            speedup: tf.summary.median / cuda.summary.median,
            smo_iters: iters,
        };
        let paper_row = paper::TABLE3.get(i).filter(|p| p.0 == per_class);
        table.row(&[
            format!("{per_class}/2"),
            format!("{:.5}", row.cuda_secs),
            format!("{:.4}", row.tf_secs),
            format!("{:.1}x", row.speedup),
            paper_row
                .map(|p| format!("{:.1}x", p.3))
                .unwrap_or_else(|| "-".into()),
        ]);
        rows.push(row);
    }
    Ok((table, rows))
}

/// One Table IV / Fig 7 row, with the overhead split by topology level
/// (`inter` = worker world, `intra` = solver sub-worlds; intra is zero
/// when `solver_ranks == 1`).
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub per_class: usize,
    pub mpi_cuda_secs: f64,
    pub multi_tf_secs: f64,
    pub speedup: f64,
    pub net_bytes: u64,
    pub net_sim_secs: f64,
    pub inter_bytes: u64,
    pub intra_bytes: u64,
    pub inter_sim_secs: f64,
    pub intra_sim_secs: f64,
}

/// Table IV: 9-class Pavia. "MPI-CUDA" = device SMO across P simulated
/// ranks; "Multi-Tensorflow" = device GD run sequentially (the paper's
/// multiple-sessions-one-GPU setup). `solver_ranks > 1` nests the
/// row-sharded solver under each worker and splits the reported overhead
/// into its inter- and intra-node parts.
pub fn run_table4(
    be: &Arc<XlaBackend>,
    sweep: &[usize],
    workers: usize,
    solver_ranks: usize,
    cfg: &BenchConfig,
    seed: u64,
) -> Result<(Table, Vec<Table4Row>)> {
    let mut table = Table::new(
        format!(
            "Table IV — multiclass training time, Pavia 9-class (P={workers}, R={solver_ranks})"
        ),
        &[
            "#samples/#classes",
            "MPI-SMO (s)",
            "Multi-GD (s)",
            "speedup",
            "paper",
            "net KiB (inter+intra)",
        ],
    );
    let mut rows = Vec::new();
    for (i, &per_class) in sweep.iter().enumerate() {
        let (ds, params) = multiclass_workload(per_class, seed);

        let smo_cfg = TrainConfig {
            workers,
            solver: Solver::Smo,
            params,
            partition: Partition::Block,
            solver_ranks: solver_ranks.max(1),
            ..Default::default()
        };

        let backend: Arc<dyn SvmBackend> = Arc::clone(be) as Arc<dyn SvmBackend>;
        let mut net = crate::cluster::NetReport::none();
        let mpi = time_train(&format!("mpi-smo-{per_class}"), cfg, || {
            let (_, r) = train_multiclass(&ds, Arc::clone(&backend), &smo_cfg).unwrap();
            net = r.net;
        });

        // Multi-TF = 36 strictly sequential, independent sessions. Every
        // OvO pair of this workload has exactly 2*per_class samples, so
        // the per-pair session cost is iid; we measure one representative
        // pair (including its graph/session construction) and scale by the
        // pair count instead of burning 36x the wall time (documented in
        // EXPERIMENTS.md; the sampling error across pairs is the bench
        // repeatability error).
        let n_pairs = crate::svm::multiclass::ovo_pairs(ds.n_classes).len();
        let pair_prob = ds.binary_pair(0, 1);
        let tf_pair = time_train(&format!("multi-gd-pair-{per_class}"), cfg, || {
            be.train_binary(&pair_prob, &params, Solver::Gd).unwrap();
        });
        let multi_tf_secs = tf_pair.summary.median * n_pairs as f64;

        let level = |name: &str| net.level(name).cloned();
        let inter = level(crate::cluster::LEVEL_INTER);
        let intra = level(crate::cluster::LEVEL_INTRA);
        let row = Table4Row {
            per_class,
            mpi_cuda_secs: mpi.summary.median,
            multi_tf_secs,
            speedup: multi_tf_secs / mpi.summary.median,
            net_bytes: net.bytes(),
            net_sim_secs: net.sim_secs(),
            inter_bytes: inter.as_ref().map_or(0, |l| l.bytes),
            intra_bytes: intra.as_ref().map_or(0, |l| l.bytes),
            inter_sim_secs: inter.as_ref().map_or(0.0, |l| l.sim_secs),
            intra_sim_secs: intra.as_ref().map_or(0.0, |l| l.sim_secs),
        };
        let paper_row = paper::TABLE4.get(i).filter(|p| p.0 == per_class);
        table.row(&[
            format!("{per_class}/9"),
            format!("{:.4}", row.mpi_cuda_secs),
            format!("{:.4}", row.multi_tf_secs),
            format!("{:.1}x", row.speedup),
            paper_row
                .map(|p| format!("{:.1}x", p.3))
                .unwrap_or_else(|| "-".into()),
            format!(
                "{:.1}+{:.1}",
                row.inter_bytes as f64 / 1024.0,
                row.intra_bytes as f64 / 1024.0
            ),
        ]);
        rows.push(row);
    }
    Ok((table, rows))
}

/// One Table V / VI row.
#[derive(Debug, Clone)]
pub struct Table56Row {
    pub dataset: String,
    pub per_class: usize,
    pub a_secs: f64,
    pub b_secs: f64,
    pub speedup: f64,
}

/// Table V: small datasets, CUDA-analog vs TF-analog (both on device).
pub fn run_table5(
    be: &XlaBackend,
    cfg: &BenchConfig,
    seed: u64,
) -> Result<(Table, Vec<Table56Row>)> {
    let mut table = Table::new(
        "Table V — binary training time (SMO-device vs GD-device)",
        &["dataset (n/d/2)", "SMO-device (s)", "GD-device (s)", "speedup", "paper"],
    );
    let mut rows = Vec::new();
    for (i, &(name, per_class, _d, ..)) in paper::TABLE5.iter().enumerate() {
        let w = binary_workload(name, per_class, seed);
        let prob = w.problem();
        let a = time_train(&format!("smo-{name}"), cfg, || {
            be.train_binary(&prob, &w.params, Solver::Smo).unwrap();
        });
        let b = time_train(&format!("gd-{name}"), cfg, || {
            be.train_binary(&prob, &w.params, Solver::Gd).unwrap();
        });
        let row = Table56Row {
            dataset: name.to_string(),
            per_class,
            a_secs: a.summary.median,
            b_secs: b.summary.median,
            speedup: b.summary.median / a.summary.median,
        };
        table.row(&[
            format!("{name} ({per_class}/{}/2)", w.ds.d),
            format!("{:.5}", row.a_secs),
            format!("{:.4}", row.b_secs),
            format!("{:.1}x", row.speedup),
            format!("{:.1}x", paper::TABLE5[i].5),
        ]);
        rows.push(row);
    }
    Ok((table, rows))
}

/// Table VI: the same GD graph on both execution providers — the paper's
/// portability experiment (TF-CPU vs TF-GPU becomes native vs XLA device).
pub fn run_table6(
    be: &XlaBackend,
    cfg: &BenchConfig,
    seed: u64,
) -> Result<(Table, Vec<Table56Row>)> {
    let native = NativeBackend::new();
    let mut table = Table::new(
        "Table VI — GD solver portability (native-host vs XLA-device, same definition)",
        &["dataset", "GD native (s)", "GD device (s)", "ratio", "paper ratio"],
    );
    let mut rows = Vec::new();
    for (i, &(name, ..)) in paper::TABLE6.iter().enumerate() {
        let per_class = paper::TABLE5[i].1; // same workloads as Table V
        let w = binary_workload(name, per_class, seed);
        let prob = w.problem();
        // Pure provider comparison: the paper's Table VI varies only the
        // device under an otherwise identical TF program, so both sides
        // here run the *same fused structure* (one training loop over a
        // cached Gram, no session model) and differ only in who executes
        // it: scalar rust vs vectorized XLA.
        let mut params = w.params;
        params.session_overhead_secs = 0.0;
        let cpu = time_train(&format!("gd-native-{name}"), cfg, || {
            native.train_binary(&prob, &params, Solver::GdFused).unwrap();
        });
        let gpu = time_train(&format!("gd-xla-{name}"), cfg, || {
            be.train_binary(&prob, &params, Solver::GdFused).unwrap();
        });
        let row = Table56Row {
            dataset: name.to_string(),
            per_class,
            a_secs: cpu.summary.median,
            b_secs: gpu.summary.median,
            speedup: cpu.summary.median / gpu.summary.median,
        };
        let (_, p_cpu, p_gpu) = paper::TABLE6[i];
        table.row(&[
            name.to_string(),
            format!("{:.4}", row.a_secs),
            format!("{:.4}", row.b_secs),
            format!("{:.2}x", row.speedup),
            format!("{:.2}x", p_cpu / p_gpu),
        ]);
        rows.push(row);
    }
    Ok((table, rows))
}
