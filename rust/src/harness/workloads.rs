//! Workload construction for the paper's experiments.

use crate::data::{self, pavia, scale::Scaler, Dataset};
use crate::svm::SvmParams;
use crate::util::rng::Rng;

/// Per-`sess.run` host overhead of a TF-1.8 python training loop —
/// interpreter dispatch, graph pruning, feed_dict marshalling. 3-10 ms/step
/// is the well-documented magnitude for small graphs of that era; we use
/// 5 ms. This is a *declared cost model* (like the MPI latency model), not
/// a measurement of this stack: our AOT/PJRT dispatch is ~100 µs, and the
/// paper's 100x+ gaps do not exist without TF's loop overhead — exactly
/// the "explicit vs implicit control" point the paper argues. The
/// `ablations` bench reports the 0-overhead variant.
pub const TF_SESSION_OVERHEAD_SECS: f64 = 5e-3;

/// Paper-matched hyper-parameters.
///
/// The paper reports none, so we use the standard defaults of its
/// ecosystem: features min-max scaled, the sklearn `gamma='scale'`
/// heuristic (see [`gamma_scale`]; callers that have the data use it —
/// this function's 1/d is the data-free libsvm fallback), C = 10,
/// tol = 1e-3, and the TF-cookbook 300-step GD budget with the
/// session-loop cost model above.
pub fn hyperparams(d: usize) -> SvmParams {
    SvmParams {
        c: 10.0,
        gamma: 1.0 / d as f32,
        tol: 1e-3,
        max_iter: 200_000,
        gd_epochs: 300,
        gd_lr: 0.01,
        session_overhead_secs: TF_SESSION_OVERHEAD_SECS,
    }
}

/// sklearn's `gamma='scale'`: 1 / (d * Var(X)) over all features jointly.
/// On min-max scaled hyperspectral data the plain 1/d underestimates by
/// ~10x (variance after scaling is ~0.05, not 1).
pub fn gamma_scale(ds: &Dataset) -> f32 {
    let n = (ds.n * ds.d).max(1) as f64;
    let mean: f64 = ds.x.iter().map(|&v| v as f64).sum::<f64>() / n;
    let var: f64 = ds.x.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / n;
    (1.0 / (ds.d as f64 * var.max(1e-6))) as f32
}

/// Hyper-parameters with the data-dependent gamma heuristic applied.
pub fn hyperparams_for(ds: &Dataset) -> SvmParams {
    let mut p = hyperparams(ds.d);
    p.gamma = gamma_scale(ds);
    p
}

/// A prepared binary training workload (paper Tables III/V rows).
#[derive(Debug, Clone)]
pub struct BinaryWorkload {
    pub name: String,
    pub ds: Dataset,
    /// The two classes forming the binary problem.
    pub pair: (usize, usize),
    pub params: SvmParams,
}

impl BinaryWorkload {
    pub fn problem(&self) -> crate::data::BinaryProblem {
        self.ds.binary_pair(self.pair.0, self.pair.1)
    }
}

/// Build a scaled binary workload: `per_class` samples from each of the
/// first two classes of `dataset`.
pub fn binary_workload(dataset: &str, per_class: usize, seed: u64) -> BinaryWorkload {
    let full = load_scaled(dataset, seed);
    let mut rng = Rng::new(seed ^ 0xB1);
    let two_class = restrict_classes(&full, &[0, 1]);
    let ds = data::per_class_subset(&two_class, per_class, &mut rng);
    BinaryWorkload {
        name: format!("{dataset}-{per_class}/2"),
        params: hyperparams_for(&ds),
        pair: (0, 1),
        ds,
    }
}

/// Deterministic two-class workload from the `synth:` scaling generator.
/// No rescaling or subsetting: the generator emits unit-scale features
/// and row `i` depends only on `(seed, i)`, so the workload is cheap to
/// rebuild at any row count — this is what the cascade scaling curve in
/// the solver ablation grows.
pub fn synth_binary_workload(rows: usize, d: usize, seed: u64) -> BinaryWorkload {
    let spec = data::SynthSpec { rows, d, classes: 2 };
    let ds = data::synth::generate(&spec, seed);
    BinaryWorkload { name: spec.name(), params: hyperparams_for(&ds), pair: (0, 1), ds }
}

/// Build the 9-class Pavia multiclass workload (paper Table IV rows).
pub fn multiclass_workload(per_class: usize, seed: u64) -> (Dataset, SvmParams) {
    let full = load_scaled("pavia", seed);
    let mut rng = Rng::new(seed ^ 0x9C);
    let ds = data::per_class_subset(&full, per_class, &mut rng);
    let params = hyperparams_for(&ds);
    (ds, params)
}

/// Load a named dataset with min-max scaling applied.
pub fn load_scaled(dataset: &str, seed: u64) -> Dataset {
    let ds = match dataset {
        // Keep the Pavia generator large enough for the 800/class sweep.
        "pavia" => pavia::generate(
            &pavia::PaviaConfig { samples_per_class: 1000, ..Default::default() },
            seed,
        ),
        other => data::by_name(other, seed)
            .unwrap_or_else(|| panic!("unknown dataset {other}")),
    };
    Scaler::fit_minmax(&ds).apply(&ds)
}

/// Project a dataset onto a subset of classes, relabelled 0..k.
pub fn restrict_classes(ds: &Dataset, classes: &[usize]) -> Dataset {
    let idx: Vec<usize> = (0..ds.n)
        .filter(|&i| classes.contains(&(ds.y[i] as usize)))
        .collect();
    let sub = ds.select(&idx);
    let remap: Vec<i32> = sub
        .y
        .iter()
        .map(|&c| classes.iter().position(|&k| k == c as usize).unwrap() as i32)
        .collect();
    Dataset::new(
        sub.name.clone(),
        sub.x,
        remap,
        sub.d,
        classes.iter().map(|&c| ds.class_names[c].clone()).collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binary_workload_shapes_match_paper() {
        let w = binary_workload("pavia", 200, 1);
        assert_eq!(w.ds.n, 400);
        assert_eq!(w.ds.d, 102);
        assert_eq!(w.ds.n_classes, 2);
        let prob = w.problem();
        assert_eq!(prob.n(), 400);
        let w_iris = binary_workload("iris", 40, 1);
        assert_eq!((w_iris.ds.n, w_iris.ds.d), (80, 4));
        let w_wdbc = binary_workload("wdbc", 190, 1);
        assert_eq!((w_wdbc.ds.n, w_wdbc.ds.d), (380, 30));
    }

    #[test]
    fn multiclass_workload_is_nine_way() {
        let (ds, p) = multiclass_workload(50, 2);
        assert_eq!(ds.n_classes, 9);
        assert_eq!(ds.n, 450);
        assert!(p.gamma > 1.0 / 102.0 && p.gamma < 10.0); // gamma="scale"
    }

    #[test]
    fn scaling_bounds_features() {
        let ds = load_scaled("wdbc", 3);
        let (lo, hi) = ds
            .feature_ranges()
            .into_iter()
            .fold((f32::MAX, f32::MIN), |a, r| (a.0.min(r.0), a.1.max(r.1)));
        assert!(lo >= -1e-6 && hi <= 1.0 + 1e-6);
    }

    #[test]
    fn restrict_relabels() {
        let ds = load_scaled("iris", 0);
        let two = restrict_classes(&ds, &[1, 2]);
        assert_eq!(two.n, 100);
        assert_eq!(two.n_classes, 2);
        assert!(two.y.iter().all(|&c| c == 0 || c == 1));
        assert_eq!(two.class_names, vec!["versicolor", "virginica"]);
    }

    #[test]
    fn synth_workload_shapes_and_determinism() {
        let w = synth_binary_workload(300, 16, 5);
        assert_eq!((w.ds.n, w.ds.d, w.ds.n_classes), (300, 16, 2));
        let prob = w.problem();
        assert_eq!(prob.n(), 300);
        let w2 = synth_binary_workload(300, 16, 5);
        assert_eq!(w.ds.x, w2.ds.x);
        assert!(w.params.gamma > 0.0);
    }

    #[test]
    fn workloads_deterministic() {
        let a = binary_workload("pavia", 100, 7);
        let b = binary_workload("pavia", 100, 7);
        assert_eq!(a.ds.x, b.ds.x);
    }
}
