//! `parasvm` — CLI launcher for the coordinator.
//!
//! Subcommands:
//!   train      train a multiclass OvO SVM across the simulated cluster
//!   eval       train + held-out accuracy
//!   serve      start the batching classifier (compiled shared-SV engine,
//!              --workers sharded serve threads, --legacy-serve for the
//!              per-pair baseline, --f16-serve for the reduced-precision
//!              pack) and drive a synthetic load
//!   bench      regenerate a paper table (--table 3|4|5|6)
//!   datasets   paper Table I inventory
//!   artifacts  list the AOT artifact registry
//!   selfcheck  device + artifact smoke test
//!
//! Common options: --dataset iris|wdbc|pavia|<csv path>, --backend
//! xla|native, --solver smo|gd, --workers N, --per-class N, --seed N,
//! --config file.json, plus hyper-parameters (--c --gamma --tol --epochs
//! --lr), interconnect (--net-latency --net-bandwidth), and the
//! million-row knobs (--cache-mb --cascade-shards --streaming --spill,
//! --dataset synth:RxDxC|*.spill) — all of which compose with
//! --solver-ranks.

use std::sync::Arc;

use parasvm::backend::{NativeBackend, SvmBackend, XlaBackend};
use parasvm::config::{BackendKind, RunConfig};
use parasvm::coordinator::train_multiclass;
use parasvm::data::{self, scale::Scaler, split, Dataset};
use parasvm::error::Result;
use parasvm::harness;
use parasvm::metrics::bench::BenchConfig;
use parasvm::runtime::{ArtifactRegistry, Device};
use parasvm::serve::{BatchPolicy, Server};
use parasvm::util::args::Args;
use parasvm::util::fmt_secs;
use parasvm::util::rng::Rng;

const FLAGS: &[&str] = &[
    "verbose",
    "help",
    "quick",
    "no-scale",
    "legacy-serve",
    "f16-serve",
    "streaming",
    "leaf-partition",
    "no-leaf-partition",
];

fn main() {
    let args = match Args::parse_with_flags(std::env::args().skip(1), FLAGS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.flag("help") || args.subcommand.is_none() || args.subcommand.as_deref() == Some("help")
    {
        print_help();
        return;
    }
    let sub = args.subcommand.clone().unwrap();
    let code = match run(&sub, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "parasvm — SVM on a hybrid simulated-MPI + PJRT accelerator stack\n\
         (reproduction of Elgarhy 2023, MPI-CUDA vs TensorFlow SVM)\n\n\
         usage: parasvm <train|eval|serve|bench|datasets|artifacts|selfcheck> [options]\n\n\
         common options:\n\
           --dataset NAME     iris | wdbc | pavia | synth:RxDxC (deterministic\n\
                              R-row, D-feature, C-class scaling generator) |\n\
                              *.csv | *.spill (packed binary spill, see --spill)\n\
                              (default iris)\n\
           --backend KIND     xla | native (default xla)\n\
           --solver NAME      smo (CUDA-analog) | smo-cached (working-set +\n\
                              LRU row cache + shrinking) | gd (TF-analog)\n\
           --workers N        simulated MPI ranks (default 4)\n\
           --pair-threads N   concurrent OvO pairs per rank (0 auto, 1 seq)\n\
           --solver-ranks N   ranks co-solving each pair's QP via the\n\
                              row-sharded distributed SMO (default 1 = off;\n\
                              >1 makes the cluster a two-level topology of\n\
                              workers x solver-ranks)\n\
           --net-inter M      inter-node link: free|shm|gige10 or LAT:BW\n\
                              (seconds : bytes/sec; default gige10)\n\
           --net-intra M      intra-node link for solver sub-worlds\n\
                              (default shm = 1e-6:1.2e10)\n\
           --row-eval T       kernel-row tier for SMO-family solvers:\n\
                              scalar | panel | panel-fused (default,\n\
                              bit-exact) | simd (explicit AVX2+FMA,\n\
                              tolerance-validated)\n\
           --per-class N      subsample N points per class\n\
           --cache-mb MB      per-rank kernel-row cache budget shared across\n\
                              all OvO pairs of a rank (0 = per-pair caches)\n\
           --cascade-shards N cascade front: shard each pair into N leaves,\n\
                              merge SVs pairwise, polish at the root\n\
                              (0/1 = direct solve)\n\
           --streaming        out-of-core chunked ingest (synth:RxDxC, CSV, or\n\
                              a *.spill file); with --cascade-shards > 1 the\n\
                              cascade trains straight off the stream, never\n\
                              holding the full matrix (no min-max scaling\n\
                              there), and composes with --solver-ranks R\n\
                              (each pool QP row-sharded across the intra\n\
                              sub-world, bit-identical to R=1)\n\
           --leaf-partition   (with --streaming --cascade-shards and\n\
                              --solver-ranks R > 1, default on) partition\n\
                              the cascade leaf pass: each rank streams and\n\
                              solves only the leaf shards it owns, then a\n\
                              survivor-gather collective rebuilds the merge\n\
                              pools everywhere — per-rank streamed bytes\n\
                              and leaf kernel work drop ~R×\n\
           --no-leaf-partition  replicated leaf pass (every rank re-streams\n\
                              and re-solves every leaf; bitwise replay of\n\
                              the pre-partition path)\n\
           --max-rescans N    cascade polish rescan bound (default 1); each\n\
                              round re-streams the source for KKT violators\n\
                              and warm-starts from the previous alpha\n\
           --spill FILE       (with --streaming --cascade-shards) parse the\n\
                              source once into a packed binary spill at FILE\n\
                              and replay every later pass from it — polish\n\
                              rescans and per-pair re-streams become page-\n\
                              cache byte copies instead of CSV re-parses\n\
           --comm-timeout S   receive timeout in seconds for every\n\
                              communicator (default 30); also the rank-\n\
                              loss detection horizon for elastic solves\n\
           --checkpoint FILE  elastic solves snapshot alpha/gradient/\n\
                              active-set here (atomic write-then-rename)\n\
                              and restore after rank loss or on restart\n\
           --checkpoint-every N  snapshot cadence in solver iterations\n\
                              (0 = never, default)\n\
           --max-rank-retries N  rank-loss recovery attempts before an\n\
                              elastic solve gives up (default 1)\n\
           --config FILE      load a JSON RunConfig (CLI flags override)\n\
           --seed N           dataset/run seed (default 42)\n\
         serve options:\n\
           --requests N       synthetic load size (default 2000)\n\
           --model FILE       serve a persisted model instead of training\n\
           --legacy-serve     per-pair baseline path (default: compiled\n\
                              shared-SV engine; --workers doubles as the\n\
                              sharded serve-thread count)\n\
           --f16-serve        quantize the compiled SV pack to f16 (half\n\
                              the pack bytes; accuracy within the\n\
                              documented delta bound, not bit-identical)\n\
         bench options:\n\
           --table N          3 | 4 | 5 | 6 (paper table to regenerate)\n\
           --quick            fewer repetitions\n\
           --out DIR          CSV output directory (default results/)"
    );
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => RunConfig::load(std::path::Path::new(path))?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn make_backend(cfg: &RunConfig) -> Result<Arc<dyn SvmBackend>> {
    Ok(match cfg.backend {
        BackendKind::Xla => Arc::new(XlaBackend::open_default()?),
        BackendKind::Native => Arc::new(NativeBackend::new().with_row_eval(cfg.row_eval)),
    })
}

/// Chunked source for `--streaming`: the synthetic generator, a CSV
/// file, or a packed binary spill (`*.spill`, from `--spill` or
/// [`data::write_spill`]) — all resettable so the cascade can re-stream
/// for polish scans.
fn make_chunk_source(cfg: &RunConfig) -> Result<Box<dyn data::ChunkSource>> {
    if cfg.dataset.starts_with("synth:") {
        let spec = data::SynthSpec::parse(&cfg.dataset)?;
        Ok(Box::new(data::SynthChunks::new(spec, cfg.seed, data::stream::DEFAULT_CHUNK_ROWS)))
    } else if cfg.dataset.ends_with(".csv") {
        Ok(Box::new(data::CsvChunks::new(
            std::path::Path::new(&cfg.dataset),
            false,
            data::stream::DEFAULT_CHUNK_ROWS,
        )))
    } else if cfg.dataset.ends_with(".spill") {
        Ok(Box::new(data::MmapChunks::new(
            std::path::Path::new(&cfg.dataset),
            data::stream::DEFAULT_CHUNK_ROWS,
        )?))
    } else {
        Err(parasvm::Error::Config(format!(
            "--streaming needs a chunked source: synth:RxDxC, a *.csv path, or a *.spill \
             file, got {:?}",
            cfg.dataset
        )))
    }
}

fn load_dataset(cfg: &RunConfig) -> Result<Dataset> {
    let raw = if cfg.streaming {
        // Chunked ingest: packs panels tile-by-tile with O(chunk) scratch;
        // bit-identical to the batch load, so the rest of the pipeline
        // (scaling, splits, training) is unchanged downstream.
        let mut src = make_chunk_source(cfg)?;
        data::ChunkedDataset::ingest(&cfg.dataset, src.as_mut())?.into_dataset()
    } else if cfg.dataset.ends_with(".csv") {
        data::csv::load(std::path::Path::new(&cfg.dataset), false)?
    } else {
        data::by_name(&cfg.dataset, cfg.seed).ok_or_else(|| {
            parasvm::Error::Config(format!(
                "unknown dataset {:?} (want iris|wdbc|pavia|synth:RxDxC|*.csv)",
                cfg.dataset
            ))
        })?
    };
    let scaled = Scaler::fit_minmax(&raw).apply(&raw);
    Ok(if cfg.per_class > 0 {
        data::per_class_subset(&scaled, cfg.per_class, &mut Rng::new(cfg.seed))
    } else {
        scaled
    })
}

fn run(sub: &str, args: &Args) -> Result<()> {
    match sub {
        "train" => cmd_train(args, false),
        "eval" => cmd_train(args, true),
        "serve" => cmd_serve(args),
        "bench" => cmd_bench(args),
        "datasets" => cmd_datasets(args),
        "artifacts" => cmd_artifacts(args),
        "selfcheck" => cmd_selfcheck(args),
        other => {
            print_help();
            Err(parasvm::Error::Config(format!("unknown subcommand {other:?}")))
        }
    }
}

fn cmd_train(args: &Args, eval: bool) -> Result<()> {
    let cfg = load_config(args)?;
    let save_path = args.opt("save").map(std::path::PathBuf::from);
    let spill_path = args.opt("spill").map(std::path::PathBuf::from);
    args.finish().map_err(parasvm::Error::Config)?;
    if cfg.streaming && cfg.cascade_shards > 1 {
        // Fully out-of-core: the cascade trains straight off the chunk
        // source, one shard resident at a time. `eval` carves a
        // deterministic held-out view out of the stream by global row
        // index and scores it through the compiled model chunk-by-chunk.
        return cmd_train_streaming_cascade(&cfg, spill_path, save_path, eval);
    }
    if spill_path.is_some() {
        return Err(parasvm::Error::Config(
            "--spill serves the out-of-core path: add --streaming --cascade-shards N, or \
             train directly off an existing spill with --dataset FILE.spill --streaming"
                .into(),
        ));
    }
    let ds = load_dataset(&cfg)?;
    let backend = make_backend(&cfg)?;
    println!(
        "training {} (n={}, d={}, classes={}) on {} / {:?}, {} worker(s)",
        ds.name, ds.n, ds.d, ds.n_classes, backend.name(), cfg.solver, cfg.workers
    );

    let (train_ds, test_ds) = if eval {
        split::stratified(&ds, cfg.train_frac, &mut Rng::new(cfg.seed ^ 0x5))
    } else {
        (ds.clone(), ds.clone())
    };

    let (model, report) = train_multiclass(&train_ds, backend, &cfg.train_config())?;
    println!(
        "trained {} binary problems in {} (makespan {}, imbalance {:.2})",
        report.pairs.len(),
        fmt_secs(report.wall_secs),
        fmt_secs(report.makespan_secs()),
        report.imbalance()
    );
    println!(
        "net: {} msgs, {} bytes, simulated wire {}",
        report.net_messages,
        report.net_bytes,
        fmt_secs(report.net_sim_secs)
    );
    for l in &report.net.levels {
        println!(
            "  level {:<5} {} msgs, {} bytes, wire {}",
            l.level,
            l.messages,
            l.bytes,
            fmt_secs(l.sim_secs)
        );
    }
    for p in &report.pairs {
        println!(
            "  pair ({},{}) rank {} n={} iters={} chunks={} sv={} {}",
            p.pos_class,
            p.neg_class,
            p.rank,
            p.n_samples,
            p.stats.iters,
            p.stats.chunks,
            p.stats.n_sv,
            fmt_secs(p.stats.total_secs()),
        );
    }
    println!("train accuracy: {:.4}", model.accuracy(&train_ds.x, &train_ds.y));
    if eval {
        println!("test  accuracy: {:.4}", model.accuracy(&test_ds.x, &test_ds.y));
    }
    if let Some(path) = save_path {
        parasvm::svm::persist::save(&model, &path)?;
        println!("model saved to {}", path.display());
    }
    Ok(())
}

/// Out-of-core cascade training: `--streaming --cascade-shards N`, with
/// three optional composers: `--spill FILE` converts the text/generator
/// stream into a packed binary spill ONCE and replays every later pass
/// (leaves, polish rescans, remaining pairs, accuracy) from it,
/// `--solver-ranks R` runs the cascade on an `intra` sub-world with
/// every pool QP row-sharded across the R ranks (and, by default, the
/// leaf pass partitioned so each rank streams/solves only the shards it
/// owns — `--no-leaf-partition` for the replicated replay), and `eval`
/// holds out every k-th row of the stream (k from `--train-frac`) and
/// scores it through the compiled model one chunk at a time.
///
/// Differences from the in-RAM path, by design:
/// * no min-max scaling — the stream is consumed as-is (`synth:` data is
///   generated pre-scaled; CSV users pre-scale themselves),
/// * no `--per-class` subsampling; the held-out split is the
///   deterministic every-k-th-row [`data::SplitChunks`] carve, not the
///   stratified shuffle,
/// * accuracy passes re-stream the source through the trained ensemble,
///   one chunk resident at a time — nothing is ever fully materialized.
fn cmd_train_streaming_cascade(
    cfg: &RunConfig,
    spill_path: Option<std::path::PathBuf>,
    save_path: Option<std::path::PathBuf>,
    eval: bool,
) -> Result<()> {
    use parasvm::svm::solver::cascade::{self, CascadeConfig};

    if matches!(cfg.solver, parasvm::backend::Solver::Gd) {
        return Err(parasvm::Error::Config(
            "--streaming --cascade-shards requires an SMO-family solver (smo|smo-cached)".into(),
        ));
    }
    if cfg.per_class > 0 {
        return Err(parasvm::Error::Config(
            "--per-class needs the in-RAM path; drop it or drop --cascade-shards".into(),
        ));
    }
    // Held-out carve for `eval`: every k-th global row, k derived from
    // --train-frac (0.8 -> every 5th row held out).
    let every = if eval {
        if cfg.train_frac >= 1.0 {
            return Err(parasvm::Error::Config(
                "eval --streaming needs --train-frac < 1 to carve a held-out split".into(),
            ));
        }
        Some(((1.0 / (1.0 - cfg.train_frac)).round() as usize).max(2))
    } else {
        None
    };
    // Optional spill: parse the source once into packed f32 rows, then
    // every later pass is byte copies out of the page cache.
    let spill_info = match &spill_path {
        Some(path) => {
            let mut src = make_chunk_source(cfg)?;
            let info = data::write_spill(src.as_mut(), path)?;
            println!(
                "spilled {} rows x {} features ({} classes) to {}",
                info.rows,
                info.d,
                info.classes,
                path.display()
            );
            Some(info)
        }
        None => None,
    };
    // Leaf size: a known row count (spill headers, synth specs) is split
    // into the requested number of shards; unknown-length CSV streams
    // fall back to fixed-size leaves.
    let known_rows = if let Some(info) = &spill_info {
        Some(info.rows)
    } else if cfg.dataset.starts_with("synth:") {
        Some(data::SynthSpec::parse(&cfg.dataset)?.rows)
    } else if cfg.dataset.ends_with(".spill") {
        let path = std::path::Path::new(&cfg.dataset);
        Some(data::MmapChunks::new(path, data::stream::DEFAULT_CHUNK_ROWS)?.rows())
    } else {
        None
    };
    // Leaf sizing targets the rows the cascade will actually see: the
    // train view when `eval` holds rows out, the whole stream otherwise.
    let train_rows = known_rows.map(|n| match every {
        Some(k) => n - n / k,
        None => n,
    });
    let shard_rows = train_rows.map_or(8192, |n| n.div_ceil(cfg.cascade_shards).max(1024));
    let ccfg = CascadeConfig {
        shards: cfg.cascade_shards,
        threads: 0,
        row_eval: cfg.row_eval,
        max_rescans: cfg.max_rescans,
        warm_start: true,
        leaf_partition: cfg.leaf_partition,
    };
    let ranks = cfg.solver_ranks.max(1);
    println!(
        "streaming cascade {}: {} ({} rows/leaf, {} rows/chunk, {} solver rank(s), \
         {} leaves, unscaled stream)",
        if eval { "eval" } else { "train" },
        cfg.dataset,
        shard_rows,
        data::stream::DEFAULT_CHUNK_ROWS,
        ranks,
        if ranks > 1 && cfg.leaf_partition { "partitioned" } else { "replicated" }
    );
    // Fresh resettable source on demand: the spill when one was written,
    // the raw stream otherwise. Every solver rank opens its own — chunk
    // streams are stateful and cannot be shared across rank threads.
    let cfg2 = cfg.clone();
    let spill2 = spill_path.clone();
    let open_raw = move || -> Result<Box<dyn data::ChunkSource>> {
        match &spill2 {
            Some(p) => Ok(Box::new(data::MmapChunks::new(p, data::stream::DEFAULT_CHUNK_ROWS)?)),
            None => make_chunk_source(&cfg2),
        }
    };
    // Training (and train accuracy) see the train view when evaluating;
    // the held view is scored separately below.
    let open_source = {
        let open_raw = open_raw.clone();
        move || -> Result<Box<dyn data::ChunkSource>> {
            Ok(match every {
                Some(k) => Box::new(data::SplitChunks::train(open_raw()?, k)),
                None => open_raw()?,
            })
        }
    };

    let t0 = std::time::Instant::now();
    let (model, stats, net, streamed) = if ranks > 1 {
        // Cascade × distributed: merge-tree and root solves are
        // row-sharded over the intra sub-world; with leaf partitioning
        // each rank streams and solves only its own leaves and the
        // survivor-gather chatter lands in the `intra` ledger below.
        // The model is identical on every rank either way.
        use parasvm::cluster::{CostModel, Topology, LEVEL_INTRA};
        let topo = Topology::single(
            LEVEL_INTRA,
            ranks,
            CostModel { latency: cfg.intra_latency, bandwidth: cfg.intra_bandwidth },
        );
        let mut universe = topo.universe();
        if cfg.comm_timeout > 0.0 {
            universe = universe
                .with_recv_timeout(std::time::Duration::from_secs_f64(cfg.comm_timeout));
        }
        let p = cfg.params;
        let open = open_source.clone();
        let outs = universe.run(move |mut comm| {
            let mut src = open()?;
            cascade::train_streaming_multiclass_on(&mut comm, src.as_mut(), shard_rows, &p, &ccfg)
        });
        let mut streamed = Vec::with_capacity(outs.len());
        let mut first = None;
        for o in outs {
            let (model, stats, bytes) = o?;
            streamed.push(bytes);
            first.get_or_insert((model, stats));
        }
        let (model, stats) = first.expect("universe ran at least one rank");
        (model, stats, Some(topo.net()), streamed)
    } else {
        let mut src = open_source()?;
        let (model, stats, bytes) =
            cascade::train_streaming_multiclass(src.as_mut(), shard_rows, &cfg.params, &ccfg)?;
        (model, stats, None, vec![bytes])
    };
    println!(
        "trained {} binary problems in {} ({} classes, d={})",
        model.binaries.len(),
        fmt_secs(t0.elapsed().as_secs_f64()),
        model.n_classes,
        model.d
    );
    for (b, st) in model.binaries.iter().zip(&stats) {
        println!(
            "  pair ({},{}) iters={} shards={} sv={} {}",
            b.pos_class,
            b.neg_class,
            st.iters,
            st.chunks,
            st.n_sv,
            fmt_secs(st.total_secs())
        );
    }
    if let Some(net) = net {
        for l in &net.levels {
            println!(
                "  level {:<5} {} msgs, {} bytes, wire {}",
                l.level,
                l.messages,
                l.bytes,
                fmt_secs(l.sim_secs)
            );
        }
    }
    for (r, b) in streamed.iter().enumerate() {
        println!("  rank {r}: {b} streamed bytes materialized");
    }
    // Accuracy by re-streaming, one chunk resident at a time, scored in
    // batches through the compiled shared-SV engine.
    let compiled = model.compile();
    let mut score = |src: &mut dyn data::ChunkSource| -> Result<f64> {
        let (mut correct, mut total) = (0usize, 0usize);
        while let Some(chunk) = src.next_chunk()? {
            let m = chunk.y.len();
            let pred = compiled.predict_batch(&chunk.x, m);
            total += m;
            correct += pred.iter().zip(&chunk.y).filter(|&(&p, &y)| p == y as usize).count();
        }
        Ok(correct as f64 / total.max(1) as f64)
    };
    let mut src = open_source()?;
    println!("train accuracy (re-streamed): {:.4}", score(src.as_mut())?);
    if let Some(k) = every {
        let mut held = data::SplitChunks::held(open_raw()?, k);
        println!("test  accuracy (held-out 1/{k} rows, re-streamed): {:.4}", score(&mut held)?);
    }
    if let Some(path) = save_path {
        parasvm::svm::persist::save(&model, &path)?;
        println!("model saved to {}", path.display());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let n_requests: usize = args
        .get("requests")
        .map_err(parasvm::Error::Config)?
        .unwrap_or(2000);
    let model_path = args.opt("model").map(std::path::PathBuf::from);
    let legacy = args.flag("legacy-serve");
    let f16 = args.flag("f16-serve");
    args.finish().map_err(parasvm::Error::Config)?;
    if legacy && f16 {
        return Err(parasvm::Error::Config(
            "--legacy-serve conflicts with --f16-serve (the legacy path has no \
             quantized pack)"
                .into(),
        ));
    }
    let ds = load_dataset(&cfg)?;
    let model = match model_path {
        Some(p) => parasvm::svm::persist::load(&p)?,
        None => {
            let backend = make_backend(&cfg)?;
            train_multiclass(&ds, backend, &cfg.train_config())?.0
        }
    };
    // `--workers` doubles as the serve shard-thread count: the compiled
    // pack is shared read-only, batches split by rows.
    let server = if legacy {
        Server::start_legacy(model, BatchPolicy::default())
    } else if f16 {
        Server::start_compiled_f16(model, BatchPolicy::default(), cfg.workers.max(1))
    } else {
        Server::start_compiled(model, BatchPolicy::default(), cfg.workers.max(1))
    };

    println!(
        "serving synthetic load: {n_requests} requests over {} [{}]",
        ds.name,
        server.engine_label()
    );
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(cfg.seed);
    let pending: Vec<_> = (0..n_requests)
        .map(|_| {
            let i = rng.below(ds.n);
            server.submit(ds.row(i).to_vec()).unwrap()
        })
        .collect();
    let mut correct_dim = 0usize;
    let mut latencies = Vec::with_capacity(n_requests);
    for rx in pending {
        let resp = rx.recv().map_err(|_| parasvm::Error::Serve("dropped".into()))?;
        correct_dim += usize::from(resp.class < ds.n_classes);
        latencies.push(resp.latency_secs);
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| {
        if latencies.is_empty() {
            0.0
        } else {
            parasvm::metrics::stats::percentile_sorted(&latencies, p)
        }
    };
    let stats = server.stats();
    println!(
        "throughput {:.0} req/s, mean latency {}, p50 {}, p99 {}, mean batch {:.1}, {} ok",
        n_requests as f64 / wall,
        fmt_secs(stats.mean_latency_secs()),
        fmt_secs(pct(50.0)),
        fmt_secs(pct(99.0)),
        stats.mean_batch_size(),
        correct_dim
    );
    server.shutdown();
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let table: u32 = args.get("table").map_err(parasvm::Error::Config)?.unwrap_or(3);
    let quick = args.flag("quick");
    let out_dir = args.opt("out").unwrap_or("results").to_string();
    let workers: usize = args.get("workers").map_err(parasvm::Error::Config)?.unwrap_or(4);
    let solver_ranks: usize = args
        .get("solver-ranks")
        .map_err(parasvm::Error::Config)?
        .unwrap_or(1);
    let seed: u64 = args.get("seed").map_err(parasvm::Error::Config)?.unwrap_or(42);
    args.finish().map_err(parasvm::Error::Config)?;

    let cfg = if quick {
        BenchConfig { warmup: 1, min_samples: 2, max_samples: 3, cv_target: 0.2 }
    } else {
        BenchConfig::heavy()
    };
    let be = Arc::new(XlaBackend::open_default()?);
    println!("{}", harness::paper::PAPER_HW);
    println!("here: XLA CPU PJRT ({} artifacts)\n", be.registry().names().len());

    let sweep = [200usize, 400, 600, 800];
    let out = std::path::Path::new(&out_dir);
    match table {
        3 => {
            let (t, _) = harness::run_table3(&be, &sweep, &cfg, seed)?;
            println!("{}", t.render());
            t.save_csv(&out.join("table3.csv"))?;
        }
        4 => {
            let (t, _) = harness::run_table4(&be, &sweep, workers, solver_ranks, &cfg, seed)?;
            println!("{}", t.render());
            t.save_csv(&out.join("table4.csv"))?;
        }
        5 => {
            let (t, _) = harness::run_table5(&be, &cfg, seed)?;
            println!("{}", t.render());
            t.save_csv(&out.join("table5.csv"))?;
        }
        6 => {
            let (t, _) = harness::run_table6(&be, &cfg, seed)?;
            println!("{}", t.render());
            t.save_csv(&out.join("table6.csv"))?;
        }
        other => {
            return Err(parasvm::Error::Config(format!("unknown table {other} (want 3-6)")))
        }
    }
    Ok(())
}

fn cmd_datasets(args: &Args) -> Result<()> {
    args.finish().map_err(parasvm::Error::Config)?;
    let mut t = parasvm::metrics::table::Table::new(
        "Table I — datasets",
        &["dataset", "#classes", "#features", "#samples", "source"],
    );
    for (name, source) in [
        ("pavia", "synthetic hyperspectral generator (paper: ROSIS Pavia Centre)"),
        ("iris", "embedded real data (Fisher 1936)"),
        ("wdbc", "synthetic WDBC-shaped generator (paper: UCI Breast Cancer)"),
    ] {
        let ds = data::by_name(name, 42).unwrap();
        t.row(&[
            name.into(),
            ds.n_classes.to_string(),
            ds.d.to_string(),
            ds.n.to_string(),
            source.into(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    args.finish().map_err(parasvm::Error::Config)?;
    let reg = ArtifactRegistry::open_default()?;
    println!("artifact registry ({} entries):", reg.names().len());
    for name in reg.names() {
        let e = reg.entry(name).unwrap();
        let shapes: Vec<String> = e
            .args
            .iter()
            .map(|a| format!("{:?}", a.shape))
            .collect();
        println!("  {name:<26} {} args: {}", e.args.len(), shapes.join(" "));
    }
    println!(
        "buckets: n={:?} d={:?} q={:?}",
        reg.buckets().n,
        reg.buckets().d,
        reg.buckets().q
    );
    Ok(())
}

fn cmd_selfcheck(args: &Args) -> Result<()> {
    args.finish().map_err(parasvm::Error::Config)?;
    let device = Device::shared()?;
    println!("PJRT platform: {}", device.platform());
    let reg = ArtifactRegistry::open_default()?;
    println!("artifacts: {} entries", reg.names().len());
    let warmed = reg.warm("n128")?;
    println!("compiled {warmed} n128 artifacts OK");

    // Micro end-to-end: train iris binary on the device, expect convergence.
    let w = harness::binary_workload("iris", 40, 1);
    let be = XlaBackend::new(Arc::new(reg));
    let (model, stats) = parasvm::backend::SvmBackend::train_binary(
        &be,
        &w.problem(),
        &w.params,
        parasvm::backend::Solver::Smo,
    )?;
    println!(
        "iris binary: converged={} iters={} sv={} in {}",
        stats.converged,
        stats.iters,
        model.n_sv(),
        fmt_secs(stats.total_secs())
    );
    if !stats.converged {
        return Err(parasvm::Error::Train("selfcheck did not converge".into()));
    }
    println!("selfcheck OK");
    Ok(())
}
