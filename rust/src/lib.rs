//! # parasvm
//!
//! SVM training and serving on a hybrid distributed/accelerator stack — a
//! full reproduction of Elgarhy, *"Support Vector Machine Implementation on
//! MPI-CUDA and Tensorflow Framework"* (CS.DC 2023) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: a simulated-MPI cluster runtime,
//!   one-vs-one multiclass scheduling (paper Fig 4), the host-side SMO
//!   convergence loop (paper Fig 3), a batching classification server, and
//!   the benchmark harness that regenerates every table/figure.
//! * **L2** (`python/compile/model.py`) — JAX graphs for both solver stacks
//!   (chunked device SMO = "CUDA"; fixed-step GD = "TensorFlow"), AOT-lowered
//!   to HLO text at build time.
//! * **L1** (`python/compile/kernels/`) — Pallas tiled RBF kernels.
//!
//! Python never runs at request time: `runtime` loads the HLO artifacts via
//! the PJRT C API (`xla` crate) and executes them from rust.

pub mod backend;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod harness;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod svm;
pub mod util;

pub use error::{Error, Result};
