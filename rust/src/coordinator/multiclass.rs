//! The hybrid multiclass driver — paper Fig 4 (`MPI-CUDA_multiSMO`).
//!
//! Rank 0 (leader) holds the dataset. Execution:
//!
//!  1. leader encodes the training set and **broadcasts** it (the paper's
//!     only pre-training communication);
//!  2. every rank derives the canonical pair list and its partition
//!     (`N = C/P` block split by default, Fig 4 step 3);
//!  3. each rank trains its binary problems on its backend — every problem
//!     internally runs the Fig 3 chunked host/device SMO loop (or the
//!     fixed-step GD graph for the TF-analog stack);
//!  4. workers send their models to the leader (**gather**, the paper's
//!     only post-training communication) which assembles the OvO ensemble.
//!
//! The returned report carries per-rank compute seconds, per-pair stats and
//! the interconnect's byte/simulated-time accounting, which feeds the
//! Table IV overhead discussion in EXPERIMENTS.md.

use std::sync::Arc;

use super::pairs::{assign, size_cost, Partition};
use super::wire;
use crate::backend::{Solver, SvmBackend};
use crate::cluster::{CostModel, Universe};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::svm::multiclass::ovo_pairs;
use crate::svm::{OvoModel, SvmParams, TrainStats};

/// Multiclass training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub workers: usize,
    pub solver: Solver,
    pub params: SvmParams,
    pub partition: Partition,
    pub net: CostModel,
    /// Concurrent binary problems per rank: each rank trains its OvO share
    /// on up to this many threads from the shared host pool instead of
    /// sequentially. 0 = auto (available cores / ranks), 1 = the paper's
    /// sequential-per-rank baseline. Model bytes and per-pair stats are
    /// emitted in canonical pair order either way, so results are
    /// bit-identical to the sequential schedule.
    pub pair_threads: usize,
    /// Second parallelism axis, orthogonal to `pair_threads`: ranks
    /// cooperating on *each* pair's QP. 1 = off (the backend's solver
    /// trains each pair alone); above 1 every binary problem is row-sharded
    /// across a sub-universe of this many ranks
    /// ([`crate::svm::solver::DistributedSmo`], host-executed, unshrunk
    /// WSS1 — so models stay bit-identical to the single-rank baseline).
    pub solver_ranks: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            workers: 4,
            solver: Solver::Smo,
            params: SvmParams::default(),
            partition: Partition::Block,
            net: CostModel::gige10(),
            pair_threads: 1,
            solver_ranks: 1,
        }
    }
}

/// Train one binary problem under the configured second parallelism axis:
/// `solver_ranks <= 1` routes to the backend's solver as before; above
/// that, the pair's SMO QP is row-sharded across a sub-universe of
/// `solver_ranks` cooperating ranks (MPI communicator-split style), which
/// composes with the per-rank `pair_threads` schedule. Only SMO-family
/// solvers have a row-sharded form — [`train_multiclass`] rejects other
/// combinations up front rather than silently substituting an algorithm.
fn train_pair(
    backend: &dyn SvmBackend,
    cfg: &TrainConfig,
    prob: &crate::data::BinaryProblem,
) -> Result<(crate::svm::BinaryModel, TrainStats)> {
    if cfg.solver_ranks > 1 {
        let engine =
            crate::svm::solver::DistributedSmo::auto(cfg.solver_ranks, prob.n(), cfg.net);
        Ok(crate::svm::solver::train_with(&engine, prob, &cfg.params))
    } else {
        backend.train_binary(prob, &cfg.params, cfg.solver)
    }
}

/// Resolve the per-rank pair concurrency: explicit value, or auto = cores
/// divided by the *total* thread demand per pair (worker ranks × solver
/// sub-ranks), so the two axes compose without oversubscribing the host.
fn resolve_pair_threads(
    requested: usize,
    ranks: usize,
    solver_ranks: usize,
    n_pairs: usize,
) -> usize {
    let t = if requested == 0 {
        (crate::svm::solver::parallel::auto_threads() / (ranks.max(1) * solver_ranks.max(1)))
            .max(1)
    } else {
        requested
    };
    t.min(n_pairs.max(1))
}

/// Per-pair outcome (classes, stats, owning rank).
#[derive(Debug, Clone)]
pub struct PairReport {
    pub pos_class: usize,
    pub neg_class: usize,
    pub rank: usize,
    pub n_samples: usize,
    pub stats: TrainStats,
}

/// Everything the harness needs to reproduce the paper's tables.
#[derive(Debug, Clone)]
pub struct MulticlassReport {
    pub wall_secs: f64,
    /// Per-rank busy seconds (compute only).
    pub rank_secs: Vec<f64>,
    pub pairs: Vec<PairReport>,
    /// Interconnect accounting.
    pub net_messages: u64,
    pub net_bytes: u64,
    pub net_sim_secs: f64,
    pub workers: usize,
}

impl MulticlassReport {
    /// Slowest rank (the multiclass makespan the paper measures).
    pub fn makespan_secs(&self) -> f64 {
        self.rank_secs.iter().copied().fold(0.0, f64::max)
    }

    /// Load imbalance: makespan / mean rank time.
    pub fn imbalance(&self) -> f64 {
        let mean = self.rank_secs.iter().sum::<f64>() / self.rank_secs.len().max(1) as f64;
        if mean > 0.0 {
            self.makespan_secs() / mean
        } else {
            1.0
        }
    }

    pub fn total_iters(&self) -> usize {
        self.pairs.iter().map(|p| p.stats.iters).sum()
    }
}

/// Train a one-vs-one multiclass SVM across the simulated cluster.
///
/// `backend` is shared by all ranks (in a real deployment each node has its
/// own device; sharing one PJRT CPU client keeps the simulation honest on a
/// single host — per-rank wall time is still measured per thread).
pub fn train_multiclass(
    ds: &Dataset,
    backend: Arc<dyn SvmBackend>,
    cfg: &TrainConfig,
) -> Result<(OvoModel, MulticlassReport)> {
    if ds.n_classes < 2 {
        return Err(Error::Train("need at least 2 classes".into()));
    }
    if cfg.solver_ranks > 1 && !matches!(cfg.solver, Solver::Smo | Solver::SmoCached) {
        return Err(Error::Train(format!(
            "solver-ranks {} requires an SMO-family solver (smo|smo-cached); {:?} has no \
             row-sharded form",
            cfg.solver_ranks, cfg.solver
        )));
    }
    let universe = Universe::new(cfg.workers, cfg.net);
    let stats = universe.stats();
    let t0 = std::time::Instant::now();

    let ds_frame = wire::encode_dataset(ds)?;
    let n_classes = ds.n_classes;
    let cfg2 = cfg.clone();

    // SPMD worker body. Rank 0 doubles as the leader.
    type RankOut = (Vec<f32>, f64, Vec<f32>); // (models frame, busy secs, pair stats frame)
    let results: Vec<Result<RankOut>> = universe.run(move |mut comm| -> Result<RankOut> {
        // (1) dataset broadcast — the only pre-training traffic.
        let frame = if comm.rank() == 0 {
            comm.bcast_f32s(0, &ds_frame)?
        } else {
            comm.bcast_f32s(0, &[])?
        };
        let local_ds = wire::decode_dataset(&frame, "bcast")?;

        // (2) canonical pair list + partition (identical on every rank).
        let pairs = ovo_pairs(n_classes);
        let counts: Vec<usize> = (0..n_classes).map(|c| local_ds.class_count(c)).collect();
        let mine = assign(pairs.len(), comm.size(), cfg2.partition, size_cost(&counts))
            [comm.rank()]
        .clone();

        // (3) train my share — the rank's pairs run concurrently on the
        // shared host pool (pair_threads strands), each strand walking a
        // contiguous stripe of the assignment. Results land in assignment
        // order, so the emitted frames match the sequential schedule.
        let busy = std::time::Instant::now();
        let probs: Vec<(usize, crate::data::BinaryProblem)> = mine
            .iter()
            .map(|&pi| {
                let (a, b) = pairs[pi];
                (pi, local_ds.binary_pair(a, b))
            })
            .collect();
        let par =
            resolve_pair_threads(cfg2.pair_threads, comm.size(), cfg2.solver_ranks, probs.len());
        type PairOut = Result<(crate::svm::BinaryModel, TrainStats)>;
        let mut outs: Vec<Option<PairOut>> = (0..probs.len()).map(|_| None).collect();
        // Fail fast like the old sequential `?` loop: the first error stops
        // every strand from starting new pairs.
        let abort = std::sync::atomic::AtomicBool::new(false);
        let order = std::sync::atomic::Ordering::Relaxed;
        if par <= 1 {
            for (slot, (_, prob)) in outs.iter_mut().zip(probs.iter()) {
                let r = train_pair(backend.as_ref(), &cfg2, prob);
                let failed = r.is_err();
                *slot = Some(r);
                if failed {
                    break;
                }
            }
        } else {
            let stripe = probs.len().div_ceil(par);
            std::thread::scope(|s| {
                let backend = &backend;
                let cfg2 = &cfg2;
                let probs = &probs;
                let abort = &abort;
                for (ci, chunk) in outs.chunks_mut(stripe).enumerate() {
                    s.spawn(move || {
                        for (off, slot) in chunk.iter_mut().enumerate() {
                            if abort.load(order) {
                                break;
                            }
                            let (_, prob) = &probs[ci * stripe + off];
                            let r = train_pair(backend.as_ref(), cfg2, prob);
                            if r.is_err() {
                                abort.store(true, order);
                            }
                            *slot = Some(r);
                        }
                    });
                }
            });
        }
        let mut models = Vec::with_capacity(probs.len());
        let mut stats_frame: Vec<f32> = Vec::new();
        // Surface the first strand error (scanning all slots: the failing
        // pair may sit at any stripe offset; later slots are then None).
        if let Some(pos) = outs.iter().position(|o| matches!(o, Some(Err(_)))) {
            let Some(Some(Err(e))) = outs.into_iter().nth(pos) else { unreachable!() };
            return Err(e);
        }
        for ((pi, prob), out) in probs.iter().zip(outs.into_iter()) {
            let (model, st) = out.ok_or_else(|| {
                Error::Train("pair result missing (training aborted)".into())
            })??;
            // pair stats frame: [pair_idx, n, iters, converged, gram_s, solve_s, chunks, n_sv]
            stats_frame.extend_from_slice(&[
                *pi as f32,
                prob.n() as f32,
                st.iters as f32,
                if st.converged { 1.0 } else { 0.0 },
                st.gram_secs as f32,
                st.solve_secs as f32,
                st.chunks as f32,
                st.n_sv as f32,
            ]);
            models.push(model);
        }
        let busy_secs = busy.elapsed().as_secs_f64();

        // (4) gather models at the leader — the only post-training traffic.
        let models_frame = wire::encode_models(&models)?;
        Ok((models_frame, busy_secs, stats_frame))
    });

    // Collect rank results (fail if any rank failed).
    let mut frames = Vec::with_capacity(cfg.workers);
    let mut rank_secs = Vec::with_capacity(cfg.workers);
    let mut stat_frames = Vec::with_capacity(cfg.workers);
    for (rank, r) in results.into_iter().enumerate() {
        let (mf, bs, sf) = r.map_err(|e| Error::Train(format!("rank {rank}: {e}")))?;
        // Account the gather explicitly (worker frames -> leader).
        if rank != 0 {
            stats.record(mf.len() * 4 + sf.len() * 4, &cfg.net);
        }
        frames.push(mf);
        rank_secs.push(bs);
        stat_frames.push(sf);
    }

    // Leader-side assembly.
    let pairs = ovo_pairs(ds.n_classes);
    let mut binaries = Vec::with_capacity(pairs.len());
    let mut pair_reports = Vec::with_capacity(pairs.len());
    for (rank, (mf, sf)) in frames.iter().zip(stat_frames.iter()).enumerate() {
        let models = wire::decode_models(mf)?;
        for (k, model) in models.into_iter().enumerate() {
            let s = &sf[k * 8..(k + 1) * 8];
            pair_reports.push(PairReport {
                pos_class: model.pos_class,
                neg_class: model.neg_class,
                rank,
                n_samples: s[1] as usize,
                stats: TrainStats {
                    iters: s[2] as usize,
                    converged: s[3] > 0.5,
                    gram_secs: s[4] as f64,
                    solve_secs: s[5] as f64,
                    chunks: s[6] as usize,
                    n_sv: s[7] as usize,
                },
            });
            binaries.push(model);
        }
    }
    // Canonical order for the ensemble (pair order, not arrival order).
    binaries.sort_by_key(|m| (m.pos_class, m.neg_class));
    pair_reports.sort_by_key(|p| (p.pos_class, p.neg_class));
    if binaries.len() != pairs.len() {
        return Err(Error::Train(format!(
            "expected {} binary models, got {}",
            pairs.len(),
            binaries.len()
        )));
    }

    let model = OvoModel::new(ds.n_classes, ds.d, binaries, ds.class_names.clone());
    let report = MulticlassReport {
        wall_secs: t0.elapsed().as_secs_f64(),
        rank_secs,
        pairs: pair_reports,
        net_messages: stats.messages(),
        net_bytes: stats.bytes(),
        net_sim_secs: stats.sim_secs(),
        workers: cfg.workers,
    };
    Ok((model, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::data::iris;

    fn quick_cfg(workers: usize) -> TrainConfig {
        TrainConfig { workers, ..Default::default() }
    }

    #[test]
    fn trains_iris_three_ways() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (model, report) = train_multiclass(&ds, be, &quick_cfg(3)).unwrap();
        assert_eq!(model.binaries.len(), 3);
        assert_eq!(report.pairs.len(), 3);
        // Iris is easy: training accuracy must be high.
        assert!(model.accuracy(&ds.x, &ds.y) >= 0.95);
        // Every pair converged and is owned by some rank < 3.
        for p in &report.pairs {
            assert!(p.stats.converged);
            assert!(p.rank < 3);
        }
    }

    #[test]
    fn worker_counts_give_same_model() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (m1, _) = train_multiclass(&ds, be.clone(), &quick_cfg(1)).unwrap();
        let (m4, _) = train_multiclass(&ds, be, &quick_cfg(4)).unwrap();
        // Same deterministic binary problems -> identical ensembles.
        for (a, b) in m1.binaries.iter().zip(m4.binaries.iter()) {
            assert_eq!(a.pos_class, b.pos_class);
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.bias, b.bias);
        }
    }

    #[test]
    fn net_accounting_scales_with_workers() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (_, r1) = train_multiclass(&ds, be.clone(), &quick_cfg(1)).unwrap();
        let (_, r4) = train_multiclass(&ds, be, &quick_cfg(4)).unwrap();
        // 1 worker: loopback only -> zero wire traffic.
        assert_eq!(r1.net_bytes, 0);
        // 4 workers: 3 bcast frames + 3 gathers.
        assert!(r4.net_bytes > 0);
        assert!(r4.net_messages >= 6);
        assert!(r4.net_sim_secs > 0.0);
    }

    #[test]
    fn parallel_pairs_give_identical_models_and_stats() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let seq = TrainConfig { workers: 2, pair_threads: 1, ..Default::default() };
        let par = TrainConfig { workers: 2, pair_threads: 3, ..Default::default() };
        let (m_seq, r_seq) = train_multiclass(&ds, be.clone(), &seq).unwrap();
        let (m_par, r_par) = train_multiclass(&ds, be, &par).unwrap();
        for (a, b) in m_seq.binaries.iter().zip(m_par.binaries.iter()) {
            assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.bias, b.bias);
        }
        // Per-pair stats preserved in canonical order under concurrency.
        assert_eq!(r_seq.pairs.len(), r_par.pairs.len());
        for (a, b) in r_seq.pairs.iter().zip(r_par.pairs.iter()) {
            assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
            assert_eq!(a.stats.iters, b.stats.iters);
            assert_eq!(a.stats.n_sv, b.stats.n_sv);
            assert_eq!(a.rank, b.rank);
        }
    }

    #[test]
    fn solver_ranks_axis_gives_bit_identical_models() {
        // The row-sharded engine (unshrunk WSS1) replays the dense oracle
        // exactly, so turning the second axis on must not perturb a single
        // coefficient — and it composes with concurrent pairs.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let base = quick_cfg(2);
        let sharded = TrainConfig { solver_ranks: 3, ..quick_cfg(2) };
        let both = TrainConfig { solver_ranks: 3, pair_threads: 2, ..quick_cfg(2) };
        let (m0, _) = train_multiclass(&ds, be.clone(), &base).unwrap();
        for cfg in [&sharded, &both] {
            let (m, r) = train_multiclass(&ds, be.clone(), cfg).unwrap();
            assert_eq!(m0.binaries.len(), m.binaries.len());
            for (a, b) in m0.binaries.iter().zip(m.binaries.iter()) {
                assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
                assert_eq!(a.coef, b.coef);
                assert_eq!(a.bias, b.bias);
            }
            for p in &r.pairs {
                assert!(p.stats.converged);
            }
        }
    }

    #[test]
    fn auto_pair_threads_resolves_sanely() {
        assert_eq!(super::resolve_pair_threads(1, 4, 1, 10), 1);
        assert_eq!(super::resolve_pair_threads(8, 4, 1, 3), 3); // capped by pairs
        assert!(super::resolve_pair_threads(0, 1, 1, 100) >= 1); // auto
        assert_eq!(super::resolve_pair_threads(0, 4, 1, 0), 1); // empty share
        // The second axis divides the auto budget: R sub-ranks per pair
        // leave at most cores/(workers*R) concurrent pairs per worker.
        let cores = crate::svm::solver::parallel::auto_threads();
        let with_subranks = super::resolve_pair_threads(0, 2, 4, 100);
        assert!(with_subranks <= (cores / 8).max(1));
    }

    #[test]
    fn solver_ranks_rejects_non_smo_solvers() {
        // No silent algorithm substitution: GD has no row-sharded form.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let cfg = TrainConfig { solver: Solver::Gd, solver_ranks: 2, ..quick_cfg(2) };
        let err = train_multiclass(&ds, be, &cfg).unwrap_err();
        assert!(err.to_string().contains("solver-ranks"), "{err}");
    }

    #[test]
    fn single_class_rejected() {
        let ds = Dataset::new("one", vec![0.0, 1.0], vec![0, 0], 1, vec!["a".into()]);
        let be = Arc::new(NativeBackend::new());
        assert!(train_multiclass(&ds, be, &quick_cfg(2)).is_err());
    }

    #[test]
    fn report_metrics_consistent() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (_, r) = train_multiclass(&ds, be, &quick_cfg(2)).unwrap();
        assert_eq!(r.rank_secs.len(), 2);
        assert!(r.makespan_secs() <= r.wall_secs + 1e-3);
        assert!(r.imbalance() >= 1.0);
        assert!(r.total_iters() > 0);
    }
}
