//! The hybrid multiclass driver — paper Fig 4 (`MPI-CUDA_multiSMO`).
//!
//! Rank 0 (leader) holds the dataset. Execution:
//!
//!  1. leader encodes the training set and **broadcasts** it over the
//!     worker-leads communicator (the paper's only pre-training
//!     communication);
//!  2. every rank derives the canonical pair list and its partition
//!     (`N = C/P` block split by default, Fig 4 step 3);
//!  3. each worker trains its binary problems — every problem internally
//!     runs the Fig 3 chunked host/device SMO loop (or the fixed-step GD
//!     graph for the TF-analog stack);
//!  4. workers send their models to the leader (**gather**, the paper's
//!     only post-training communication) which assembles the OvO ensemble.
//!
//! # The two-level machine
//!
//! The cluster is a [`Topology`], not a flat universe. With
//! `solver_ranks == 1` the world is the flat PR-2 machine: one `inter`
//! level of `workers` ranks, each rank training whole pairs (optionally
//! `pair_threads` at a time on host threads). With `solver_ranks = R > 1`
//! the world is `workers × R` ranks: every world rank derives, via
//! [`crate::cluster::Comm::split_with`],
//!
//!  * its **intra** communicator (color = worker): the R-rank solver
//!    sub-world that co-solves each of the worker's pairs through
//!    [`crate::svm::solver::distributed::solve_on`], priced by the fast
//!    intra-node link and accounted into the `intra` ledger;
//!  * its **peer** communicator (color = slot): slot-0 ranks form the
//!    worker-leads world that carries the dataset broadcast and the model
//!    gather on the slow inter-node link (`inter` ledger) — exactly the
//!    PR-2 world when R == 1.
//!
//! A worker's R ranks are one MPI group, so its pairs train sequentially
//! over the intra communicator (`pair_threads` applies to the flat path;
//! the leftover core budget instead feeds each rank's row-evaluation
//! threads). Models are bit-identical across every (workers,
//! solver_ranks, pair_threads) combination — the unshrunk distributed
//! engine replays the single-rank trajectory exactly.
//!
//! The million-row knobs compose with the second axis: `--cache-mb`
//! gives every solver rank a persistent [`SharedKernelCache`] serving
//! its column window across the worker's sequential pairs (cross-pair
//! reuse counted and summed into [`MulticlassReport::shared_cache`];
//! still bit-identical), and `--cascade-shards` runs the warm-started
//! cascade driver replicated on the sub-world with every pool solve
//! row-sharded across it ([`cascade::solve_on`]; agreement-pinned like
//! the flat cascade).
//!
//! The returned report carries per-worker compute seconds, per-pair stats
//! and the interconnect's per-level byte/simulated-time accounting
//! ([`MulticlassReport::net`]), which is what splits the Table IV
//! overhead discussion into its inter- and intra-node parts.

use std::sync::Arc;

use super::pairs::{assign, size_cost, Partition};
use super::wire;
use crate::backend::{Solver, SvmBackend};
use crate::cluster::{CostModel, FaultReport, NetReport, Topology};
use crate::data::{BinaryProblem, Dataset};
use crate::error::{Error, Result};
use crate::svm::multiclass::ovo_pairs;
use crate::svm::solver::cascade::{self, CascadeConfig};
use crate::svm::solver::{
    model_from_outcome, working_set, CacheStats, EngineConfig, KernelSource, SharedKernelCache,
    SolveOutcome,
};
use crate::svm::{BinaryModel, OvoModel, SvmParams, TrainStats};

/// Multiclass training configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub workers: usize,
    pub solver: Solver,
    pub params: SvmParams,
    pub partition: Partition,
    /// Inter-node link: the worker world (dataset bcast, model gather).
    pub net: CostModel,
    /// Intra-node link: the solver sub-worlds under each worker
    /// (per-iteration candidate collectives when `solver_ranks > 1`).
    pub intra_net: CostModel,
    /// Concurrent binary problems per rank (flat path only): each rank
    /// trains its OvO share on up to this many threads from the shared
    /// host pool instead of sequentially. 0 = auto (available cores /
    /// topology ranks), 1 = the paper's sequential-per-rank baseline.
    /// Model bytes and per-pair stats are emitted in canonical pair order
    /// either way, so results are bit-identical to the sequential
    /// schedule. Ignored when `solver_ranks > 1` — the worker's solver
    /// group co-solves its pairs one at a time, as a real MPI group would.
    pub pair_threads: usize,
    /// Second parallelism axis: ranks cooperating on *each* pair's QP.
    /// 1 = off (the backend's solver trains each pair alone); above 1 the
    /// world becomes `workers × solver_ranks` and every binary problem is
    /// row-sharded across the worker's intra communicator
    /// ([`crate::svm::solver::DistributedSmo`], host-executed, unshrunk
    /// WSS1 — so models stay bit-identical to the single-rank baseline).
    pub solver_ranks: usize,
    /// Row-evaluation tier for the hierarchical path's per-rank window
    /// caches (`solver_ranks > 1`). The exact tiers keep the bit-identity
    /// guarantee above; [`crate::svm::solver::RowEval::Simd`] relaxes it
    /// to the documented tolerance. The flat path's tier is the
    /// backend's own knob (`NativeBackend::with_row_eval`) — this field
    /// only steers solves the coordinator drives itself.
    pub row_eval: crate::svm::solver::RowEval,
    /// Per-rank shared kernel-row cache budget in MiB (`--cache-mb`).
    /// 0 = off (each pair solve keeps its private per-solve cache). On,
    /// every rank builds ONE [`SharedKernelCache`] over its replicated
    /// dataset and all of its OvO pair solves — concurrent ones included
    /// — share it: the budget bounds the *rank*, not each pair, and rows
    /// a pair computed are hits for every later pair touching the same
    /// classes ([`CacheStats::cross_pair_hits`]). Models are bit-identical
    /// to the private-cache engine. SMO-family solvers only. With
    /// `solver_ranks > 1` every solver rank keeps its own cache and
    /// serves its column window from it
    /// ([`SharedKernelCache::window_source`]); the report sums the
    /// worker's per-rank counters.
    pub cache_mb: usize,
    /// Cascade front leaf shards (`--cascade-shards`). 0/1 = off (direct
    /// solve); above 1 every pair trains through
    /// [`cascade::solve`]: shard → SV tree merge → polish, warm-starting
    /// each merge from its children. NOT bit-identical to direct —
    /// pinned by [`cascade::CASCADE_AGREEMENT_MIN`] prediction
    /// agreement. SMO-family solvers only; takes precedence over
    /// `cache_mb`. With `solver_ranks > 1` the cascade driver runs
    /// replicated on the worker's sub-world and every pool solve is
    /// row-sharded across it ([`cascade::solve_on`]).
    pub cascade_shards: usize,
    /// Partition the *streaming* cascade's leaf pass across solver ranks
    /// (`--leaf-partition`, default on): each rank streams and solves
    /// only the leaf shards it owns, then the survivor-gather collective
    /// rebuilds the merge pools everywhere. The in-RAM cascade here is
    /// already replicated over materialized data, so this knob only
    /// changes runs driven through
    /// [`cascade::solve_streaming_on`] — it is carried in the
    /// [`CascadeConfig`] either way so one config describes both paths.
    pub leaf_partition: bool,
    /// Cascade polish rescan bound (`--max-rescans`): full-pass KKT
    /// rescans after the root solve, each warm-started from the previous
    /// round's alpha via the seeded distributed solve (counted in
    /// `warm_solves`). 0 accepts the root solution as-is.
    pub max_rescans: usize,
    /// Receive timeout for every communicator in the run, in seconds
    /// (`--comm-timeout`). 0 = the library default (30s). The world
    /// universe is built with this horizon and every derived comm
    /// (intra solver sub-worlds, worker-leads peers) inherits it — it is
    /// both the hang-detection bound and, for elastic solves, the
    /// failure-detection horizon.
    pub comm_timeout: f64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            workers: 4,
            solver: Solver::Smo,
            params: SvmParams::default(),
            partition: Partition::Block,
            net: CostModel::gige10(),
            intra_net: CostModel::shm(),
            pair_threads: 1,
            solver_ranks: 1,
            row_eval: crate::svm::solver::RowEval::default(),
            cache_mb: 0,
            cascade_shards: 0,
            leaf_partition: true,
            max_rescans: 1,
            comm_timeout: 0.0,
        }
    }
}

impl TrainConfig {
    /// The machine this configuration trains on: flat when the second
    /// axis is off, the paper's two-level `workers × solver_ranks`
    /// hierarchy when it is on.
    pub fn topology(&self) -> Topology {
        if self.solver_ranks > 1 {
            Topology::two_level(self.workers, self.net, self.solver_ranks, self.intra_net)
        } else {
            Topology::flat(self.workers, self.net)
        }
    }
}

/// Resolve the per-rank pair concurrency for the flat path: explicit
/// value, or auto = available cores divided by the number of rank threads
/// the topology actually spawns — so neither axis under- nor
/// over-subscribes the host.
fn resolve_pair_threads(requested: usize, topology_ranks: usize, n_pairs: usize) -> usize {
    let t = if requested == 0 {
        (crate::svm::solver::parallel::auto_threads() / topology_ranks.max(1)).max(1)
    } else {
        requested
    };
    t.min(n_pairs.max(1))
}

/// Per-pair outcome (classes, stats, owning worker).
#[derive(Debug, Clone)]
pub struct PairReport {
    pub pos_class: usize,
    pub neg_class: usize,
    pub rank: usize,
    pub n_samples: usize,
    pub stats: TrainStats,
}

/// Everything the harness needs to reproduce the paper's tables.
#[derive(Debug, Clone)]
pub struct MulticlassReport {
    pub wall_secs: f64,
    /// Per-worker busy seconds (compute only; the lead rank's clock when
    /// the worker is a solver group).
    pub rank_secs: Vec<f64>,
    pub pairs: Vec<PairReport>,
    /// Interconnect accounting split by topology level (`inter` workers,
    /// `intra` solver sub-worlds). The Table-IV overhead split.
    pub net: NetReport,
    /// Roll-ups of [`MulticlassReport::net`] across levels.
    pub net_messages: u64,
    pub net_bytes: u64,
    pub net_sim_secs: f64,
    pub workers: usize,
    /// Shared per-rank kernel-cache counters aggregated over all ranks
    /// (counters summed, `max_resident` maxed). All-zero when
    /// [`TrainConfig::cache_mb`] is 0. `cross_pair_hits > 0` is the
    /// signal the cross-pair sharing actually fired.
    pub shared_cache: CacheStats,
    /// Recovery ledger summed over all workers' pair solves (rank-loss
    /// detections, resharding rounds, checkpoint restores, wasted
    /// iterations). All-zero ([`FaultReport::none`]) on fault-free runs —
    /// today's coordinator paths solve fail-fast, so a non-zero ledger
    /// can only come from elastic solves feeding the per-worker trailer.
    pub fault: FaultReport,
    /// Bytes of row data materialized from chunk streams, summed over the
    /// workers' trailers. Always zero on the in-RAM coordinator paths
    /// here (they materialize everything up front, streaming nothing);
    /// the out-of-core CLI path reports its per-rank counters directly
    /// from [`cascade::StreamingOutcome::streamed_bytes`]. The slot
    /// exists so the wire format and report already carry the counter
    /// when a streaming coordinator path lands.
    pub streamed_bytes: u64,
}

impl MulticlassReport {
    /// Slowest worker (the multiclass makespan the paper measures).
    pub fn makespan_secs(&self) -> f64 {
        self.rank_secs.iter().copied().fold(0.0, f64::max)
    }

    /// Load imbalance: makespan / mean worker time.
    pub fn imbalance(&self) -> f64 {
        let mean = self.rank_secs.iter().sum::<f64>() / self.rank_secs.len().max(1) as f64;
        if mean > 0.0 {
            self.makespan_secs() / mean
        } else {
            1.0
        }
    }

    pub fn total_iters(&self) -> usize {
        self.pairs.iter().map(|p| p.stats.iters).sum()
    }
}

/// Train a one-vs-one multiclass SVM across the simulated cluster.
///
/// `backend` is shared by all ranks (in a real deployment each node has its
/// own device; sharing one PJRT CPU client keeps the simulation honest on a
/// single host — per-rank wall time is still measured per thread).
pub fn train_multiclass(
    ds: &Dataset,
    backend: Arc<dyn SvmBackend>,
    cfg: &TrainConfig,
) -> Result<(OvoModel, MulticlassReport)> {
    if ds.n_classes < 2 {
        return Err(Error::Train("need at least 2 classes".into()));
    }
    if cfg.solver_ranks > 1 && !matches!(cfg.solver, Solver::Smo | Solver::SmoCached) {
        return Err(Error::Train(format!(
            "solver-ranks {} requires an SMO-family solver (smo|smo-cached); {:?} has no \
             row-sharded form",
            cfg.solver_ranks, cfg.solver
        )));
    }
    if (cfg.cache_mb > 0 || cfg.cascade_shards > 1)
        && !matches!(cfg.solver, Solver::Smo | Solver::SmoCached)
    {
        return Err(Error::Train(format!(
            "--cache-mb/--cascade-shards require an SMO-family solver (smo|smo-cached); {:?} \
             has no kernel-row cache or cascade form",
            cfg.solver
        )));
    }
    let topo = cfg.topology();
    let mut universe = topo.universe();
    if cfg.comm_timeout > 0.0 {
        universe = universe.with_recv_timeout(std::time::Duration::from_secs_f64(cfg.comm_timeout));
    }
    let t0 = std::time::Instant::now();

    let ds_frame = Arc::new(wire::encode_dataset(ds)?);
    let n_classes = ds.n_classes;
    let cfg2 = cfg.clone();
    let r = cfg.solver_ranks.max(1);
    let w_total = cfg.workers;
    let total_ranks = topo.total_ranks();
    let inter_stats = topo.level_stats(0);
    let intra_stats = topo.level_stats(topo.levels().len() - 1);
    // The leftover core budget feeds each rank's row-evaluation threads on
    // the hierarchical path (thread count never changes the numbers).
    let engine_threads =
        (crate::svm::solver::parallel::auto_threads() / total_ranks.max(1)).max(1);

    // SPMD body for every world rank. Slot-0 ranks are worker leads; world
    // rank 0 doubles as the leader.
    type RankOut = (Vec<f32>, f64, Vec<f32>); // (models frame, busy secs, pair stats frame)
    let results: Vec<Result<RankOut>> = universe.run(move |mut comm| -> Result<RankOut> {
        let worker = comm.rank() / r;
        let slot = comm.rank() % r;

        // Derive the per-level communicators (collective over the world).
        let mut intra =
            comm.split_with(worker, slot, cfg2.intra_net, Arc::clone(&intra_stats))?;
        let mut peers = comm.split_with(slot, worker, cfg2.net, Arc::clone(&inter_stats))?;

        // (1) dataset broadcast over the worker-leads communicator — the
        // only pre-training inter-node traffic (peer rank == worker index,
        // so root 0 is the leader). Non-lead solver ranks read the
        // replicated frame in-process: their node already holds the data
        // once the lead has it, exactly as PR 2's per-solve Arc replication
        // assumed.
        let lead_frame;
        let frame: &[f32] = if slot == 0 {
            lead_frame = peers.bcast_f32s(0, &ds_frame)?;
            &lead_frame
        } else {
            &ds_frame
        };
        let local_ds = wire::decode_dataset(frame, "bcast")?;

        // The rank's ONE shared kernel-row cache (`--cache-mb`, SMO
        // paths): every pair solve below — concurrent ones included —
        // reads and fills the same budgeted LRU of full-width global
        // rows. On the hierarchical path each of the worker's R ranks
        // keeps its own cache and serves its column window from it
        // (`SharedKernelCache::window_source`), so rows persist across
        // the worker's sequential pair solves there too.
        let shared = (cfg2.cache_mb > 0 && cfg2.cascade_shards <= 1).then(|| {
            SharedKernelCache::new(
                &local_ds.x,
                local_ds.n,
                local_ds.d,
                cfg2.params.gamma,
                SharedKernelCache::budget_rows_for_mb(cfg2.cache_mb, local_ds.n),
                engine_threads,
            )
            .with_eval(cfg2.row_eval)
        });

        // (2) canonical pair list + partition over *workers* (identical on
        // every rank).
        let pairs = ovo_pairs(n_classes);
        let counts: Vec<usize> = (0..n_classes).map(|c| local_ds.class_count(c)).collect();
        let mine =
            assign(pairs.len(), w_total, cfg2.partition, size_cost(&counts))[worker].clone();

        // (3) train my worker's share. Flat path: the pairs run
        // concurrently on the shared host pool (pair_threads strands),
        // each strand walking a contiguous stripe of the assignment.
        // Hierarchical path: the worker's solver group co-solves each pair
        // sequentially over the intra communicator. Results land in
        // assignment order either way, so the emitted frames match the
        // sequential schedule.
        let busy = std::time::Instant::now();
        let probs: Vec<(usize, crate::data::BinaryProblem)> = mine
            .iter()
            .map(|&pi| {
                let (a, b) = pairs[pi];
                (pi, local_ds.binary_pair(a, b))
            })
            .collect();
        let par = if r > 1 {
            1
        } else {
            resolve_pair_threads(cfg2.pair_threads, total_ranks, probs.len())
        };
        // Recovery ledger for this rank's solves. Only the hierarchical
        // (sequential) path can contribute; the flat path solves are
        // fail-fast and leave it zero.
        let mut fault = FaultReport::none();
        type PairOut = Result<(crate::svm::BinaryModel, TrainStats)>;
        let mut outs: Vec<Option<PairOut>> = (0..probs.len()).map(|_| None).collect();
        // Fail fast like the old sequential `?` loop: the first error stops
        // every strand from starting new pairs.
        let abort = std::sync::atomic::AtomicBool::new(false);
        let order = std::sync::atomic::Ordering::Relaxed;
        if par <= 1 {
            for (slot_out, (pi, prob)) in outs.iter_mut().zip(probs.iter()) {
                let out = if r > 1 {
                    solve_hier_pair(
                        &mut intra,
                        &cfg2,
                        engine_threads,
                        shared.as_ref(),
                        &local_ds,
                        pairs[*pi],
                        prob,
                        &mut fault,
                    )
                } else {
                    solve_flat_pair(
                        backend.as_ref(),
                        &cfg2,
                        engine_threads,
                        shared.as_ref(),
                        &local_ds,
                        pairs[*pi],
                        prob,
                    )
                };
                let failed = out.is_err();
                *slot_out = Some(out);
                if failed {
                    break;
                }
            }
        } else {
            let stripe = probs.len().div_ceil(par);
            std::thread::scope(|s| {
                let backend = &backend;
                let cfg2 = &cfg2;
                let probs = &probs;
                let abort = &abort;
                let shared = &shared;
                let local_ds = &local_ds;
                let pairs = &pairs;
                for (ci, chunk) in outs.chunks_mut(stripe).enumerate() {
                    s.spawn(move || {
                        for (off, slot_out) in chunk.iter_mut().enumerate() {
                            if abort.load(order) {
                                break;
                            }
                            let (pi, prob) = &probs[ci * stripe + off];
                            let out = solve_flat_pair(
                                backend.as_ref(),
                                cfg2,
                                engine_threads,
                                shared.as_ref(),
                                local_ds,
                                pairs[*pi],
                                prob,
                            );
                            if out.is_err() {
                                abort.store(true, order);
                            }
                            *slot_out = Some(out);
                        }
                    });
                }
            });
        }
        // Surface the first strand error on every rank (scanning all
        // slots: the failing pair may sit at any stripe offset; later
        // slots are then None).
        if let Some(pos) = outs.iter().position(|o| matches!(o, Some(Err(_)))) {
            let Some(Some(Err(e))) = outs.into_iter().nth(pos) else { unreachable!() };
            return Err(e);
        }
        let busy_secs = busy.elapsed().as_secs_f64();
        // Worker-wide shared-cache counters. Flat path: the rank's own
        // cache. Hierarchical path: every solver rank holds its own
        // window cache, so the counters are exchanged over intra
        // (collective — all R ranks participate) and summed; the lead
        // reports the worker total in its trailer below.
        let cs = match shared.as_ref().map(|c| c.stats()) {
            Some(s) if r > 1 => {
                let frames = intra.allgather_u64s(&[
                    s.hits,
                    s.misses,
                    s.evictions,
                    s.cross_pair_hits,
                    s.max_resident as u64,
                ])?;
                let mut agg = CacheStats::default();
                for f in &frames {
                    agg.hits += f[0];
                    agg.misses += f[1];
                    agg.evictions += f[2];
                    agg.cross_pair_hits += f[3];
                    agg.max_resident = agg.max_resident.max(f[4] as usize);
                }
                agg
            }
            Some(s) => s,
            None => CacheStats::default(),
        };
        if slot != 0 {
            // Non-lead solver ranks hold replicated results; only the lead
            // speaks for the worker.
            return Ok((Vec::new(), busy_secs, Vec::new()));
        }
        let mut models = Vec::with_capacity(probs.len());
        let mut stats_frame: Vec<f32> = Vec::new();
        for ((pi, prob), out) in probs.iter().zip(outs.into_iter()) {
            let (model, st) = out.ok_or_else(|| {
                Error::Train("pair result missing (training aborted)".into())
            })??;
            // pair stats frame: [pair_idx, n, iters, converged, gram_s, solve_s, chunks, n_sv]
            stats_frame.extend_from_slice(&[
                *pi as f32,
                prob.n() as f32,
                st.iters as f32,
                if st.converged { 1.0 } else { 0.0 },
                st.gram_secs as f32,
                st.solve_secs as f32,
                st.chunks as f32,
                st.n_sv as f32,
            ]);
            models.push(model);
        }
        // Per-worker trailer after the per-pair records: the shared-cache
        // counters [hits, misses, evictions, cross_pair_hits,
        // max_resident] (zeros when the shared cache is off; summed over
        // the worker's solver ranks on the hierarchical path), the
        // recovery ledger [detections, resharding_rounds, restores,
        // wasted_iters] (zeros on fail-fast paths), and the per-worker
        // streamed-bytes counter — always zero here because every
        // coordinator path materializes its data up front; only the
        // out-of-core CLI path (`cascade::train_streaming_multiclass_on`)
        // streams, and it reports per rank directly. Counts are exact in
        // f32 up to 2^24 — plenty for all three.
        stats_frame.extend_from_slice(&[
            cs.hits as f32,
            cs.misses as f32,
            cs.evictions as f32,
            cs.cross_pair_hits as f32,
            cs.max_resident as f32,
            fault.detections as f32,
            fault.resharding_rounds as f32,
            fault.restores as f32,
            fault.wasted_iters as f32,
            0.0, // streamed_bytes: in-RAM paths never stream
        ]);

        // (4) gather models at the leader — the only post-training
        // traffic. Frames travel by thread join (in-process); the transfer
        // is accounted below on the leads' inter-node level.
        let models_frame = wire::encode_models(&models)?;
        Ok((models_frame, busy_secs, stats_frame))
    });

    // Collect per-worker results from the lead ranks (fail if any world
    // rank failed) and account the gather on the inter level.
    let gather_stats = topo.level_stats(0);
    let mut frames = Vec::with_capacity(w_total);
    let mut rank_secs = Vec::with_capacity(w_total);
    let mut stat_frames = Vec::with_capacity(w_total);
    for (world_rank, res) in results.into_iter().enumerate() {
        let (mf, bs, sf) = res.map_err(|e| Error::Train(format!("rank {world_rank}: {e}")))?;
        if world_rank % r != 0 {
            continue;
        }
        if world_rank != 0 {
            gather_stats.record(mf.len() * 4 + sf.len() * 4, &cfg.net);
        }
        frames.push(mf);
        rank_secs.push(bs);
        stat_frames.push(sf);
    }

    // Leader-side assembly.
    let pairs = ovo_pairs(ds.n_classes);
    let mut binaries = Vec::with_capacity(pairs.len());
    let mut pair_reports = Vec::with_capacity(pairs.len());
    let mut shared_cache = CacheStats::default();
    let mut fault = FaultReport::none();
    let mut streamed_bytes = 0u64;
    for (worker, (mf, sf)) in frames.iter().zip(stat_frames.iter()).enumerate() {
        let models = wire::decode_models(mf)?;
        let n_models = models.len();
        for (k, model) in models.into_iter().enumerate() {
            let s = &sf[k * 8..(k + 1) * 8];
            pair_reports.push(PairReport {
                pos_class: model.pos_class,
                neg_class: model.neg_class,
                rank: worker,
                n_samples: s[1] as usize,
                stats: TrainStats {
                    iters: s[2] as usize,
                    converged: s[3] > 0.5,
                    gram_secs: s[4] as f64,
                    solve_secs: s[5] as f64,
                    chunks: s[6] as usize,
                    n_sv: s[7] as usize,
                },
            });
            binaries.push(model);
        }
        let tail = &sf[n_models * 8..];
        if tail.len() == 10 {
            shared_cache.hits += tail[0] as u64;
            shared_cache.misses += tail[1] as u64;
            shared_cache.evictions += tail[2] as u64;
            shared_cache.cross_pair_hits += tail[3] as u64;
            shared_cache.max_resident = shared_cache.max_resident.max(tail[4] as usize);
            fault.merge(&FaultReport {
                detections: tail[5] as u64,
                resharding_rounds: tail[6] as u64,
                restores: tail[7] as u64,
                wasted_iters: tail[8] as u64,
            });
            streamed_bytes += tail[9] as u64;
        }
    }
    // Canonical order for the ensemble (pair order, not arrival order).
    binaries.sort_by_key(|m| (m.pos_class, m.neg_class));
    pair_reports.sort_by_key(|p| (p.pos_class, p.neg_class));
    if binaries.len() != pairs.len() {
        return Err(Error::Train(format!(
            "expected {} binary models, got {}",
            pairs.len(),
            binaries.len()
        )));
    }

    let model = OvoModel::new(ds.n_classes, ds.d, binaries, ds.class_names.clone());
    let net = topo.net();
    let report = MulticlassReport {
        wall_secs: t0.elapsed().as_secs_f64(),
        rank_secs,
        pairs: pair_reports,
        net_messages: net.messages(),
        net_bytes: net.bytes(),
        net_sim_secs: net.sim_secs(),
        net,
        workers: cfg.workers,
        shared_cache,
        fault,
        streamed_bytes,
    };
    Ok((model, report))
}

/// One flat-path pair solve, routed by the training knobs: the cascade
/// front (`--cascade-shards`), the rank's shared kernel-row cache
/// (`--cache-mb`), or the backend's own engine. The engine configuration
/// depends only on `cfg` — never on the pair-threads schedule — so
/// concurrent and sequential runs produce bit-identical models.
fn solve_flat_pair(
    backend: &dyn SvmBackend,
    cfg: &TrainConfig,
    engine_threads: usize,
    shared: Option<&SharedKernelCache<'_>>,
    ds: &Dataset,
    ab: (usize, usize),
    prob: &BinaryProblem,
) -> Result<(BinaryModel, TrainStats)> {
    if cfg.cascade_shards > 1 {
        let ccfg = CascadeConfig {
            shards: cfg.cascade_shards,
            threads: engine_threads,
            row_eval: cfg.row_eval,
            max_rescans: cfg.max_rescans,
            warm_start: true,
            leaf_partition: cfg.leaf_partition,
        };
        let out = cascade::solve(prob, &cfg.params, &ccfg);
        return Ok(model_from_outcome(prob, &out.outcome, &cfg.params));
    }
    if let Some(cache) = shared {
        let t0 = std::time::Instant::now();
        let mut src = cache.pair_source(ds.pair_indices(ab.0, ab.1));
        // cache_rows is inert here (the shared cache already exists);
        // everything else matches the private cached+shrink engine.
        let ecfg = EngineConfig {
            threads: engine_threads,
            row_eval: cfg.row_eval,
            ..EngineConfig::cached_shrink(0)
        };
        let (solution, shrink) = working_set::solve(&mut src, &prob.y, &cfg.params, &ecfg);
        let out = SolveOutcome {
            solution,
            cache: src.stats(),
            shrink,
            gram_secs: 0.0,
            solve_secs: t0.elapsed().as_secs_f64(),
            net: NetReport::none(),
            fault: FaultReport::none(),
        };
        return Ok(model_from_outcome(prob, &out, &cfg.params));
    }
    backend.train_binary(prob, &cfg.params, cfg.solver)
}

/// One hierarchical-path pair solve: the worker's R-rank intra world
/// co-solves the QP collectively. Routing mirrors [`solve_flat_pair`]:
/// the cascade front first (`--cascade-shards`, every pool solve
/// row-sharded across the sub-world), then the rank-persistent shared
/// window cache (`--cache-mb`, cross-pair reuse counted per rank), then
/// the private per-solve window caches. The non-cascade routes stay
/// bit-identical to the flat single-rank baseline. Each solve's recovery
/// ledger is merged into `fault` (zero today — these entry points are
/// fail-fast — but the wire format already carries it to the leader).
#[allow(clippy::too_many_arguments)]
fn solve_hier_pair(
    intra: &mut crate::cluster::Comm,
    cfg: &TrainConfig,
    engine_threads: usize,
    shared: Option<&SharedKernelCache<'_>>,
    ds: &Dataset,
    ab: (usize, usize),
    prob: &BinaryProblem,
    fault: &mut FaultReport,
) -> Result<(BinaryModel, TrainStats)> {
    use crate::svm::solver::{distributed, DistributedSmo, RowSlice};
    if cfg.cascade_shards > 1 {
        let ccfg = CascadeConfig {
            shards: cfg.cascade_shards,
            threads: engine_threads,
            row_eval: cfg.row_eval,
            max_rescans: cfg.max_rescans,
            warm_start: true,
            leaf_partition: cfg.leaf_partition,
        };
        let out = cascade::solve_on(intra, prob, &cfg.params, &ccfg)?;
        fault.merge(&out.outcome.fault);
        return Ok(model_from_outcome(prob, &out.outcome, &cfg.params));
    }
    let engine = DistributedSmo::auto(intra.size(), prob.n(), cfg.intra_net)
        .with_threads(engine_threads)
        .with_eval(cfg.row_eval);
    let out = if let Some(cache) = shared {
        let cols = RowSlice::partition(prob.n(), intra.size())[intra.rank()];
        let mut src = cache.window_source(ds.pair_indices(ab.0, ab.1), cols);
        distributed::solve_on_source(intra, &mut src, &prob.y, &cfg.params, &engine.cfg, None)?
    } else {
        distributed::solve_on(intra, prob, &cfg.params, &engine.cfg)?
    };
    fault.merge(&out.fault);
    Ok(model_from_outcome(prob, &out, &cfg.params))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::NativeBackend;
    use crate::cluster::{LEVEL_INTER, LEVEL_INTRA};
    use crate::data::iris;

    fn quick_cfg(workers: usize) -> TrainConfig {
        TrainConfig { workers, ..Default::default() }
    }

    #[test]
    fn trains_iris_three_ways() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (model, report) = train_multiclass(&ds, be, &quick_cfg(3)).unwrap();
        assert_eq!(model.binaries.len(), 3);
        assert_eq!(report.pairs.len(), 3);
        // Iris is easy: training accuracy must be high.
        assert!(model.accuracy(&ds.x, &ds.y) >= 0.95);
        // Every pair converged and is owned by some worker < 3.
        for p in &report.pairs {
            assert!(p.stats.converged);
            assert!(p.rank < 3);
        }
    }

    #[test]
    fn worker_counts_give_same_model() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (m1, _) = train_multiclass(&ds, be.clone(), &quick_cfg(1)).unwrap();
        let (m4, _) = train_multiclass(&ds, be, &quick_cfg(4)).unwrap();
        // Same deterministic binary problems -> identical ensembles.
        for (a, b) in m1.binaries.iter().zip(m4.binaries.iter()) {
            assert_eq!(a.pos_class, b.pos_class);
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.bias, b.bias);
        }
    }

    #[test]
    fn net_accounting_scales_with_workers() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (_, r1) = train_multiclass(&ds, be.clone(), &quick_cfg(1)).unwrap();
        let (_, r4) = train_multiclass(&ds, be, &quick_cfg(4)).unwrap();
        // 1 worker: loopback only -> zero wire traffic.
        assert_eq!(r1.net_bytes, 0);
        // 4 workers: 3 bcast frames + 3 gathers.
        assert!(r4.net_bytes > 0);
        assert!(r4.net_messages >= 6);
        assert!(r4.net_sim_secs > 0.0);
        // Flat runs are single-level: everything is inter-node traffic.
        assert_eq!(r4.net.levels.len(), 1);
        assert_eq!(r4.net.level(LEVEL_INTER).unwrap().bytes, r4.net_bytes);
    }

    #[test]
    fn parallel_pairs_give_identical_models_and_stats() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let seq = TrainConfig { workers: 2, pair_threads: 1, ..Default::default() };
        let par = TrainConfig { workers: 2, pair_threads: 3, ..Default::default() };
        let (m_seq, r_seq) = train_multiclass(&ds, be.clone(), &seq).unwrap();
        let (m_par, r_par) = train_multiclass(&ds, be, &par).unwrap();
        for (a, b) in m_seq.binaries.iter().zip(m_par.binaries.iter()) {
            assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.bias, b.bias);
        }
        // Per-pair stats preserved in canonical order under concurrency.
        assert_eq!(r_seq.pairs.len(), r_par.pairs.len());
        for (a, b) in r_seq.pairs.iter().zip(r_par.pairs.iter()) {
            assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
            assert_eq!(a.stats.iters, b.stats.iters);
            assert_eq!(a.stats.n_sv, b.stats.n_sv);
            assert_eq!(a.rank, b.rank);
        }
    }

    #[test]
    fn solver_ranks_axis_gives_bit_identical_models() {
        // The row-sharded engine (unshrunk WSS1) replays the dense oracle
        // exactly, so turning the second axis on must not perturb a single
        // coefficient — and pair_threads must stay inert on the
        // hierarchical path.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let base = quick_cfg(2);
        let sharded = TrainConfig { solver_ranks: 3, ..quick_cfg(2) };
        let both = TrainConfig { solver_ranks: 3, pair_threads: 2, ..quick_cfg(2) };
        let (m0, _) = train_multiclass(&ds, be.clone(), &base).unwrap();
        for cfg in [&sharded, &both] {
            let (m, r) = train_multiclass(&ds, be.clone(), cfg).unwrap();
            assert_eq!(m0.binaries.len(), m.binaries.len());
            for (a, b) in m0.binaries.iter().zip(m.binaries.iter()) {
                assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
                assert_eq!(a.coef, b.coef);
                assert_eq!(a.bias, b.bias);
            }
            for p in &r.pairs {
                assert!(p.stats.converged);
            }
        }
    }

    #[test]
    fn hierarchical_run_splits_traffic_by_level() {
        // W=2 x R=2: the report must carry both levels, the solver
        // chatter must land on intra, the bcast/gather on inter, and the
        // roll-up must equal the level sum.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let flat = quick_cfg(2);
        let hier = TrainConfig { solver_ranks: 2, ..quick_cfg(2) };
        let (_, r_flat) = train_multiclass(&ds, be.clone(), &flat).unwrap();
        let (_, r_hier) = train_multiclass(&ds, be, &hier).unwrap();
        assert_eq!(r_hier.net.levels.len(), 2);
        let inter = r_hier.net.level(LEVEL_INTER).unwrap();
        let intra = r_hier.net.level(LEVEL_INTRA).unwrap();
        // The inter level still carries exactly the flat world's traffic:
        // same dataset bcast to the same worker leads, same model gather
        // (models are bit-identical, hence byte-identical frames).
        assert_eq!(inter.bytes, r_flat.net_bytes);
        assert_eq!(inter.messages, r_flat.net_messages);
        // The solver sub-worlds really crossed their own wire.
        assert!(intra.bytes > 0);
        assert!(intra.messages > 0);
        // Roll-up = level sum.
        assert_eq!(r_hier.net_bytes, inter.bytes + intra.bytes);
        assert_eq!(r_hier.net_messages, inter.messages + intra.messages);
        assert!(
            (r_hier.net_sim_secs - (inter.sim_secs + intra.sim_secs)).abs() < 1e-12
        );
    }

    #[test]
    fn auto_pair_threads_resolves_sanely() {
        assert_eq!(super::resolve_pair_threads(1, 4, 10), 1);
        assert_eq!(super::resolve_pair_threads(8, 4, 3), 3); // capped by pairs
        assert!(super::resolve_pair_threads(0, 1, 100) >= 1); // auto
        assert_eq!(super::resolve_pair_threads(0, 4, 0), 1); // empty share
        // Auto divides the host budget by the ranks the topology actually
        // spawns — a flat 2-worker run divides by 2, not by 2 x
        // solver_ranks (single-axis runs no longer under-subscribe).
        let cores = crate::svm::solver::parallel::auto_threads();
        assert_eq!(
            super::resolve_pair_threads(0, 2, 1000),
            (cores / 2).max(1)
        );
        // An 8-rank hierarchy leaves at most cores/8 strands.
        assert!(super::resolve_pair_threads(0, 8, 1000) <= (cores / 8).max(1));
    }

    #[test]
    fn shared_cache_is_deterministic_across_pair_threads() {
        // One rank, three iris pairs, one shared cache: the pair-threads
        // schedule may reorder who computes a row first, but every kernel
        // entry is the same f32 expression, so models are bit-identical.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let base = TrainConfig {
            workers: 1,
            solver: Solver::SmoCached,
            cache_mb: 16,
            ..Default::default()
        };
        let par = TrainConfig { pair_threads: 3, ..base.clone() };
        let (m1, r1) = train_multiclass(&ds, be.clone(), &base).unwrap();
        let (m3, r3) = train_multiclass(&ds, be, &par).unwrap();
        assert!(m1.accuracy(&ds.x, &ds.y) >= 0.95);
        for (a, b) in m1.binaries.iter().zip(m3.binaries.iter()) {
            assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.bias, b.bias);
        }
        // Sequential schedule: each class's rows are computed by the first
        // pair touching them and hit cross-pair for the second.
        assert!(r1.shared_cache.hits > 0);
        assert!(r1.shared_cache.cross_pair_hits > 0, "{:?}", r1.shared_cache);
        assert!(r1.shared_cache.max_resident > 0);
        // Concurrent schedule: the hit/miss *split* is interleaving-
        // dependent, but sharing still fires.
        assert!(r3.shared_cache.hits > 0);
    }

    #[test]
    fn cascade_flat_path_trains_accurately() {
        // Iris is class-sorted, so leaf shards are single-class and pass
        // through unsolved — the worst case the cascade must survive.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let cfg = TrainConfig {
            workers: 2,
            solver: Solver::SmoCached,
            cascade_shards: 4,
            ..Default::default()
        };
        let (model, report) = train_multiclass(&ds, be, &cfg).unwrap();
        assert_eq!(model.binaries.len(), 3);
        assert!(model.accuracy(&ds.x, &ds.y) >= 0.95);
        for p in &report.pairs {
            assert!(p.stats.converged);
            assert!(p.stats.n_sv > 0);
        }
        // Cascade runs leave the shared-cache trailer zeroed, and in-RAM
        // paths stream nothing.
        assert_eq!(report.shared_cache.hits, 0);
        assert_eq!(report.streamed_bytes, 0);
    }

    #[test]
    fn cache_and_cascade_knobs_reject_bad_combos() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let gd = TrainConfig { solver: Solver::Gd, cache_mb: 16, ..quick_cfg(2) };
        let err = train_multiclass(&ds, be, &gd).unwrap_err();
        assert!(err.to_string().contains("cache-mb"), "{err}");
    }

    #[test]
    fn hierarchical_cascade_trains_and_reports_intra_traffic() {
        // cascade x distributed: W=2 workers, each pair's cascade pools
        // row-sharded across an R=2 solver sub-world. Iris is
        // class-sorted (single-class leaves), the cascade's worst case.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let cfg = TrainConfig {
            workers: 2,
            solver_ranks: 2,
            solver: Solver::SmoCached,
            cascade_shards: 4,
            ..Default::default()
        };
        let (model, report) = train_multiclass(&ds, be, &cfg).unwrap();
        assert_eq!(model.binaries.len(), 3);
        assert!(model.accuracy(&ds.x, &ds.y) >= 0.95);
        for p in &report.pairs {
            assert!(p.stats.converged);
            assert!(p.stats.n_sv > 0);
        }
        // The pool solves' candidate collectives land on the intra level.
        let intra = report.net.level(LEVEL_INTRA).unwrap();
        assert!(intra.bytes > 0, "cascade pool solves never crossed the intra wire");
        assert!(report.net.level(LEVEL_INTER).unwrap().bytes > 0);
    }

    #[test]
    fn hierarchical_shared_cache_is_bit_identical_and_counts_cross_pair_hits() {
        // --cache-mb x --solver-ranks: per-rank window caches persist
        // across the worker's sequential pairs. The window gathers the
        // same f32 kernel entries the private sliced caches evaluate, so
        // models must equal the flat baseline bit-for-bit — and class-0
        // rows computed for pair (0,1) must hit cross-pair for (0,2).
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (m0, _) = train_multiclass(&ds, be.clone(), &quick_cfg(2)).unwrap();
        let cfg = TrainConfig { solver_ranks: 2, cache_mb: 8, ..quick_cfg(2) };
        let (m, r) = train_multiclass(&ds, be, &cfg).unwrap();
        for (a, b) in m0.binaries.iter().zip(m.binaries.iter()) {
            assert_eq!((a.pos_class, a.neg_class), (b.pos_class, b.neg_class));
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.bias, b.bias);
        }
        assert!(r.shared_cache.hits > 0);
        assert!(r.shared_cache.cross_pair_hits > 0, "{:?}", r.shared_cache);
        assert!(r.shared_cache.max_resident > 0);
    }

    #[test]
    fn solver_ranks_rejects_non_smo_solvers() {
        // No silent algorithm substitution: GD has no row-sharded form.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let cfg = TrainConfig { solver: Solver::Gd, solver_ranks: 2, ..quick_cfg(2) };
        let err = train_multiclass(&ds, be, &cfg).unwrap_err();
        assert!(err.to_string().contains("solver-ranks"), "{err}");
    }

    #[test]
    fn single_class_rejected() {
        let ds = Dataset::new("one", vec![0.0, 1.0], vec![0, 0], 1, vec!["a".into()]);
        let be = Arc::new(NativeBackend::new());
        assert!(train_multiclass(&ds, be, &quick_cfg(2)).is_err());
    }

    #[test]
    fn report_metrics_consistent() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (_, r) = train_multiclass(&ds, be, &quick_cfg(2)).unwrap();
        assert_eq!(r.rank_secs.len(), 2);
        assert!(r.makespan_secs() <= r.wall_secs + 1e-3);
        assert!(r.imbalance() >= 1.0);
        assert!(r.total_iters() > 0);
    }

    #[test]
    fn fault_ledger_is_zero_and_comm_timeout_is_inert_on_healthy_runs() {
        // --comm-timeout only moves the hang-detection horizon; on a
        // healthy cluster it must not perturb a single coefficient, and
        // the recovery ledger must stay all-zero on both paths.
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let (m0, r0) = train_multiclass(&ds, be.clone(), &quick_cfg(2)).unwrap();
        assert!(!r0.fault.any(), "{:?}", r0.fault);
        let cfg = TrainConfig { solver_ranks: 2, comm_timeout: 10.0, ..quick_cfg(2) };
        let (m, r) = train_multiclass(&ds, be, &cfg).unwrap();
        assert!(!r.fault.any(), "{:?}", r.fault);
        for (a, b) in m0.binaries.iter().zip(m.binaries.iter()) {
            assert_eq!(a.coef, b.coef);
            assert_eq!(a.bias, b.bias);
        }
    }

    #[test]
    fn hierarchical_report_has_one_entry_per_worker() {
        let ds = iris::load();
        let be = Arc::new(NativeBackend::new());
        let cfg = TrainConfig { solver_ranks: 2, ..quick_cfg(3) };
        let (_, r) = train_multiclass(&ds, be, &cfg).unwrap();
        assert_eq!(r.rank_secs.len(), 3, "one busy clock per worker, not per world rank");
        assert_eq!(r.workers, 3);
        for p in &r.pairs {
            assert!(p.rank < 3);
        }
    }
}
