//! Wire codec for datasets and binary models over the simulated
//! interconnect — all-f32 framing so the cost model accounts the same
//! byte volume a real MPI implementation would move.
//!
//! Frames are self-describing little vectors of f32:
//!   dataset: [n, d, n_classes, y..., x...]
//!   model:   [pos, neg, d, n_sv, bias, gamma, coef..., sv...]
//! Counts < 2^24 are exactly representable in f32 (asserted).

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::svm::BinaryModel;

fn push_count(out: &mut Vec<f32>, v: usize, what: &str) -> Result<()> {
    if v >= (1 << 24) {
        return Err(Error::Cluster(format!("{what} {v} too large for f32 wire count")));
    }
    out.push(v as f32);
    Ok(())
}

fn read_count(v: f32, what: &str) -> Result<usize> {
    if v < 0.0 || v.fract() != 0.0 {
        return Err(Error::Cluster(format!("bad wire count for {what}: {v}")));
    }
    Ok(v as usize)
}

/// Encode a dataset (features + labels, no class names — those ride along
/// out of band since only rank 0 reports).
pub fn encode_dataset(ds: &Dataset) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(3 + ds.n + ds.x.len());
    push_count(&mut out, ds.n, "n")?;
    push_count(&mut out, ds.d, "d")?;
    push_count(&mut out, ds.n_classes, "n_classes")?;
    out.extend(ds.y.iter().map(|&c| c as f32));
    out.extend_from_slice(&ds.x);
    Ok(out)
}

pub fn decode_dataset(buf: &[f32], name: &str) -> Result<Dataset> {
    if buf.len() < 3 {
        return Err(Error::Cluster("dataset frame too short".into()));
    }
    let n = read_count(buf[0], "n")?;
    let d = read_count(buf[1], "d")?;
    let n_classes = read_count(buf[2], "n_classes")?;
    let need = 3 + n + n * d;
    if buf.len() != need {
        return Err(Error::Cluster(format!(
            "dataset frame length {} != expected {need}",
            buf.len()
        )));
    }
    let y: Vec<i32> = buf[3..3 + n].iter().map(|&v| v as i32).collect();
    let x = buf[3 + n..].to_vec();
    let class_names = (0..n_classes).map(|c| format!("class{c}")).collect();
    Ok(Dataset::new(name, x, y, d, class_names))
}

/// Encode a trained binary model.
pub fn encode_model(m: &BinaryModel) -> Result<Vec<f32>> {
    let mut out = Vec::with_capacity(6 + m.coef.len() + m.sv.len());
    push_count(&mut out, m.pos_class, "pos_class")?;
    push_count(&mut out, m.neg_class, "neg_class")?;
    push_count(&mut out, m.d, "d")?;
    push_count(&mut out, m.n_sv(), "n_sv")?;
    out.push(m.bias);
    out.push(m.gamma);
    out.extend_from_slice(&m.coef);
    out.extend_from_slice(&m.sv);
    Ok(out)
}

pub fn decode_model(buf: &[f32]) -> Result<BinaryModel> {
    if buf.len() < 6 {
        return Err(Error::Cluster("model frame too short".into()));
    }
    let pos_class = read_count(buf[0], "pos_class")?;
    let neg_class = read_count(buf[1], "neg_class")?;
    let d = read_count(buf[2], "d")?;
    let n_sv = read_count(buf[3], "n_sv")?;
    let bias = buf[4];
    let gamma = buf[5];
    let need = 6 + n_sv + n_sv * d;
    if buf.len() != need {
        return Err(Error::Cluster(format!(
            "model frame length {} != expected {need}",
            buf.len()
        )));
    }
    let coef = buf[6..6 + n_sv].to_vec();
    let sv = buf[6 + n_sv..].to_vec();
    Ok(BinaryModel { sv, coef, d, bias, gamma, pos_class, neg_class })
}

/// Concatenate several model frames with a leading count per frame.
pub fn encode_models(models: &[BinaryModel]) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    push_count(&mut out, models.len(), "n_models")?;
    for m in models {
        let frame = encode_model(m)?;
        push_count(&mut out, frame.len(), "frame_len")?;
        out.extend(frame);
    }
    Ok(out)
}

pub fn decode_models(buf: &[f32]) -> Result<Vec<BinaryModel>> {
    if buf.is_empty() {
        return Err(Error::Cluster("models frame empty".into()));
    }
    let n = read_count(buf[0], "n_models")?;
    let mut out = Vec::with_capacity(n);
    let mut pos = 1usize;
    for _ in 0..n {
        let len = read_count(
            *buf.get(pos).ok_or_else(|| Error::Cluster("models frame truncated".into()))?,
            "frame_len",
        )?;
        pos += 1;
        let end = pos + len;
        if end > buf.len() {
            return Err(Error::Cluster("models frame truncated".into()));
        }
        out.push(decode_model(&buf[pos..end])?);
        pos = end;
    }
    if pos != buf.len() {
        return Err(Error::Cluster("models frame has trailing data".into()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::iris;

    #[test]
    fn dataset_roundtrip() {
        let ds = iris::load();
        let enc = encode_dataset(&ds).unwrap();
        let back = decode_dataset(&enc, "iris").unwrap();
        assert_eq!(back.n, ds.n);
        assert_eq!(back.d, ds.d);
        assert_eq!(back.y, ds.y);
        assert_eq!(back.x, ds.x);
    }

    #[test]
    fn model_roundtrip() {
        let m = BinaryModel {
            sv: vec![1.0, 2.0, 3.0, 4.0],
            coef: vec![0.5, -0.5],
            d: 2,
            bias: 0.25,
            gamma: 0.7,
            pos_class: 3,
            neg_class: 8,
        };
        let back = decode_model(&encode_model(&m).unwrap()).unwrap();
        assert_eq!(back.sv, m.sv);
        assert_eq!(back.coef, m.coef);
        assert_eq!((back.pos_class, back.neg_class, back.d), (3, 8, 2));
        assert_eq!((back.bias, back.gamma), (0.25, 0.7));
    }

    #[test]
    fn multi_model_roundtrip() {
        let mk = |pos: usize| BinaryModel {
            sv: vec![pos as f32],
            coef: vec![1.0],
            d: 1,
            bias: 0.0,
            gamma: 1.0,
            pos_class: pos,
            neg_class: pos + 1,
        };
        let models = vec![mk(0), mk(1), mk(2)];
        let back = decode_models(&encode_models(&models).unwrap()).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[2].pos_class, 2);
    }

    #[test]
    fn corrupt_frames_rejected() {
        assert!(decode_dataset(&[1.0], "x").is_err());
        assert!(decode_model(&[0.0, 1.0, 2.0]).is_err());
        assert!(decode_models(&[]).is_err());
        // bad count
        assert!(decode_model(&[0.5, 1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 1.0]).is_err());
        // trailing garbage
        let m = BinaryModel {
            sv: vec![1.0],
            coef: vec![1.0],
            d: 1,
            bias: 0.0,
            gamma: 1.0,
            pos_class: 0,
            neg_class: 1,
        };
        let mut enc = encode_models(&[m]).unwrap();
        enc.push(9.0);
        assert!(decode_models(&enc).is_err());
    }

    #[test]
    fn empty_model_list_roundtrips() {
        let back = decode_models(&encode_models(&[]).unwrap()).unwrap();
        assert!(back.is_empty());
    }
}
