//! L3 coordinator — the paper's system contribution.
//!
//! * [`pairs`] — one-vs-one task decomposition and partitioning over
//!   workers: the paper's static block split (Fig 4, `N = C/P`) plus
//!   round-robin and LPT (longest-processing-time) strategies as ablations.
//! * [`multiclass`] — the hybrid driver (paper Fig 4): rank 0 broadcasts
//!   the training set over the simulated interconnect, every rank trains
//!   its share of the m(m-1)/2 binary problems on its backend (each binary
//!   problem internally runs the Fig 3 host/device chunk loop), and rank 0
//!   gathers the models into an [`crate::svm::OvoModel`]. With
//!   `solver_ranks > 1` the cluster is the paper's two-level machine
//!   ([`crate::cluster::Topology`]): each worker's pairs are co-solved by
//!   a solver sub-communicator split from the world, and the report
//!   splits interconnect overhead by level (inter vs intra — Table IV).
//! * [`wire`] — compact f32 wire codec for datasets and models so the
//!   cost model sees realistic byte counts.

pub mod multiclass;
pub mod pairs;
pub mod wire;

pub use multiclass::{train_multiclass, MulticlassReport, TrainConfig};
pub use pairs::Partition;
