//! One-vs-one pair scheduling: which worker trains which binary problem.

use crate::svm::multiclass::ovo_pairs;

/// Partitioning strategy for distributing the m(m-1)/2 binary problems.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partition {
    /// Contiguous blocks of ceil(C/P) — exactly the paper's Fig 4.
    Block,
    /// Cyclic assignment (pair i -> worker i mod P).
    RoundRobin,
    /// Longest-processing-time-first greedy using per-pair cost estimates
    /// (sum of the two class sizes — SMO cost grows with n). Extension over
    /// the paper; ablated in `benches/ablations.rs`.
    Lpt,
}

impl std::str::FromStr for Partition {
    type Err = String;

    fn from_str(s: &str) -> Result<Partition, String> {
        match s {
            "block" => Ok(Partition::Block),
            "round_robin" | "rr" => Ok(Partition::RoundRobin),
            "lpt" => Ok(Partition::Lpt),
            other => Err(format!("unknown partition {other:?} (want block|rr|lpt)")),
        }
    }
}

/// Assign pair indices `0..n_pairs` to `workers` buckets.
///
/// `cost` estimates the work of pair `i` (only used by Lpt).
pub fn assign(
    n_pairs: usize,
    workers: usize,
    strategy: Partition,
    cost: impl Fn(usize) -> f64,
) -> Vec<Vec<usize>> {
    assert!(workers > 0);
    let mut out = vec![Vec::new(); workers];
    match strategy {
        Partition::Block => {
            // ceil(C/P) contiguous chunk per worker (paper Fig 4 step 3).
            let chunk = n_pairs.div_ceil(workers);
            for i in 0..n_pairs {
                out[(i / chunk.max(1)).min(workers - 1)].push(i);
            }
        }
        Partition::RoundRobin => {
            for i in 0..n_pairs {
                out[i % workers].push(i);
            }
        }
        Partition::Lpt => {
            let mut order: Vec<usize> = (0..n_pairs).collect();
            order.sort_by(|&a, &b| cost(b).partial_cmp(&cost(a)).unwrap());
            let mut load = vec![0.0f64; workers];
            for i in order {
                let w = (0..workers)
                    .min_by(|&a, &b| load[a].partial_cmp(&load[b]).unwrap())
                    .unwrap();
                out[w].push(i);
                load[w] += cost(i);
            }
            for bucket in &mut out {
                bucket.sort_unstable(); // deterministic per-worker order
            }
        }
    }
    out
}

/// Per-pair cost estimate from class sizes: the binary problem over classes
/// (a, b) has |a| + |b| samples; SMO iterations and Gram cost grow with it.
pub fn size_cost(class_counts: &[usize]) -> impl Fn(usize) -> f64 + '_ {
    let pairs = ovo_pairs(class_counts.len());
    move |i: usize| {
        let (a, b) = pairs[i];
        (class_counts[a] + class_counts[b]) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(assignment: &[Vec<usize>]) -> Vec<usize> {
        let mut v: Vec<usize> = assignment.iter().flatten().copied().collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn block_matches_paper_fig4() {
        // 36 pairs (9 classes) over 4 workers -> 9 contiguous each.
        let a = assign(36, 4, Partition::Block, |_| 1.0);
        assert_eq!(a.iter().map(Vec::len).collect::<Vec<_>>(), vec![9, 9, 9, 9]);
        assert_eq!(a[0], (0..9).collect::<Vec<_>>());
        assert_eq!(a[3], (27..36).collect::<Vec<_>>());
    }

    #[test]
    fn every_strategy_covers_exactly_once() {
        for strategy in [Partition::Block, Partition::RoundRobin, Partition::Lpt] {
            for workers in 1..8 {
                for n in [1usize, 3, 10, 36] {
                    let a = assign(n, workers, strategy, |i| (i + 1) as f64);
                    assert_eq!(flat(&a), (0..n).collect::<Vec<_>>(), "{strategy:?} {workers} {n}");
                }
            }
        }
    }

    #[test]
    fn round_robin_balanced_within_one() {
        let a = assign(10, 4, Partition::RoundRobin, |_| 1.0);
        let lens: Vec<usize> = a.iter().map(Vec::len).collect();
        assert_eq!(lens, vec![3, 3, 2, 2]);
    }

    #[test]
    fn lpt_beats_block_on_skewed_costs() {
        // One huge pair + many small: block puts the huge one with others,
        // LPT isolates it.
        let cost = |i: usize| if i == 0 { 100.0 } else { 1.0 };
        let makespan = |a: &[Vec<usize>]| {
            a.iter()
                .map(|b| b.iter().map(|&i| cost(i)).sum::<f64>())
                .fold(0.0, f64::max)
        };
        let block = assign(8, 4, Partition::Block, cost);
        let lpt = assign(8, 4, Partition::Lpt, cost);
        assert!(makespan(&lpt) <= makespan(&block));
        assert_eq!(makespan(&lpt), 100.0); // the huge pair runs alone
    }

    #[test]
    fn more_workers_than_pairs() {
        let a = assign(2, 5, Partition::Block, |_| 1.0);
        assert_eq!(flat(&a), vec![0, 1]);
        assert!(a.iter().filter(|b| !b.is_empty()).count() <= 2);
    }

    #[test]
    fn size_cost_uses_class_counts() {
        let counts = [10usize, 20, 30];
        let cost = size_cost(&counts);
        // pairs: (0,1)=30, (0,2)=40, (1,2)=50
        assert_eq!(cost(0), 30.0);
        assert_eq!(cost(1), 40.0);
        assert_eq!(cost(2), 50.0);
    }
}
