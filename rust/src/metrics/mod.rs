//! Metrics substrate: timing, summary statistics, table rendering, CSV
//! emission, and a micro-benchmark runner (criterion is unavailable in the
//! offline build environment, so `bench` implements warmup + repeated
//! sampling + robust statistics itself).

pub mod bench;
pub mod stats;
pub mod table;

use std::time::Instant;

/// Simple scoped stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn lap(&mut self) -> f64 {
        let t = self.start.elapsed().as_secs_f64();
        self.start = Instant::now();
        t
    }
}

/// Monotonic counters keyed by static names (cheap, single-threaded).
#[derive(Debug, Default, Clone)]
pub struct Counters {
    entries: std::collections::BTreeMap<&'static str, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, key: &'static str, v: u64) {
        *self.entries.entry(key).or_insert(0) += v;
    }

    pub fn get(&self, key: &'static str) -> u64 {
        self.entries.get(key).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.entries.iter().map(|(k, v)| (*k, *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_measures() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert!(sw.elapsed_secs() >= 0.009);
    }

    #[test]
    fn counters_accumulate() {
        let mut c = Counters::new();
        c.add("x", 2);
        c.add("x", 3);
        assert_eq!(c.get("x"), 5);
        assert_eq!(c.get("y"), 0);
        assert_eq!(c.iter().count(), 1);
    }
}
