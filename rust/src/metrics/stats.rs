//! Summary statistics over f64 samples.

/// Summary of a sample set (times in seconds, or any unit).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub median: f64,
    pub std_dev: f64,
    pub min: f64,
    pub max: f64,
    pub p95: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample set");
        let n = samples.len();
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            median: percentile_sorted(&sorted, 50.0),
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// Relative std dev (coefficient of variation).
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < 1e-300 {
            0.0
        } else {
            self.std_dev / self.mean
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let w = rank - lo as f64;
        sorted[lo] * (1.0 - w) + sorted[hi] * w
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std_dev - 2.0f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!((s.mean, s.median, s.min, s.max, s.p95), (7.0, 7.0, 7.0, 7.0, 7.0));
        assert_eq!(s.std_dev, 0.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::of(&[5.0, 1.0, 3.0]);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.min, 1.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_rejected() {
        Summary::of(&[]);
    }
}
