//! Paper-style table rendering (monospace) + CSV emission.
//!
//! The reproduction harness prints tables in the same row/column layout as
//! the paper (Tables III–VI) and mirrors each to a CSV file so the figures
//! (Figs 6–7 are plots of the same series) can be regenerated elsewhere.

use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render as an aligned monospace table.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line_of = |ch: char, widths: &[usize]| {
            let mut s = String::from("+");
            for w in widths {
                for _ in 0..w + 2 {
                    s.push(ch);
                }
                s.push('+');
            }
            s
        };
        let sep = line_of('-', &widths);
        let _ = writeln!(out, "{sep}");
        let mut hdr = String::from("|");
        for i in 0..ncol {
            let _ = write!(hdr, " {:<w$} |", self.headers[i], w = widths[i]);
        }
        let _ = writeln!(out, "{hdr}");
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let mut line = String::from("|");
            for i in 0..ncol {
                let _ = write!(line, " {:>w$} |", row[i], w = widths[i]);
            }
            let _ = writeln!(out, "{line}");
        }
        let _ = writeln!(out, "{sep}");
        out
    }

    /// CSV form (RFC-4180-ish: quote cells containing commas/quotes).
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

/// An ASCII scatter/line plot of (x, series...) — stands in for the paper's
/// Figs 6 and 7 in terminal output.
pub struct AsciiPlot {
    pub title: String,
    pub width: usize,
    pub height: usize,
}

impl AsciiPlot {
    pub fn new(title: impl Into<String>) -> Self {
        AsciiPlot { title: title.into(), width: 64, height: 16 }
    }

    /// `series`: (label, points); y is auto-scaled (log10 when the spread
    /// exceeds 100x, like the paper's training-time plots).
    pub fn render(&self, series: &[(&str, Vec<(f64, f64)>)]) -> String {
        let pts: Vec<(f64, f64)> =
            series.iter().flat_map(|(_, p)| p.iter().copied()).collect();
        if pts.is_empty() {
            return format!("## {}\n(no data)\n", self.title);
        }
        let (xmin, xmax) = pts.iter().fold((f64::MAX, f64::MIN), |a, p| {
            (a.0.min(p.0), a.1.max(p.0))
        });
        let ys: Vec<f64> = pts.iter().map(|p| p.1).collect();
        let (ymin_raw, ymax_raw) = ys.iter().fold((f64::MAX, f64::MIN), |a, &v| {
            (a.0.min(v), a.1.max(v))
        });
        let log = ymin_raw > 0.0 && ymax_raw / ymin_raw > 100.0;
        let ty = |v: f64| if log { v.log10() } else { v };
        let (ymin, ymax) = (ty(ymin_raw), ty(ymax_raw));

        let mut grid = vec![vec![' '; self.width]; self.height];
        let marks = ['o', 'x', '*', '+', '#'];
        for (si, (_, points)) in series.iter().enumerate() {
            for &(x, y) in points {
                let cx = if xmax > xmin {
                    ((x - xmin) / (xmax - xmin) * (self.width - 1) as f64).round() as usize
                } else {
                    0
                };
                let cy = if ymax > ymin {
                    ((ty(y) - ymin) / (ymax - ymin) * (self.height - 1) as f64).round() as usize
                } else {
                    0
                };
                grid[self.height - 1 - cy][cx.min(self.width - 1)] =
                    marks[si % marks.len()];
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {} {}", self.title, if log { "(log y)" } else { "" });
        for (i, row) in grid.iter().enumerate() {
            let yv = ymax - (ymax - ymin) * i as f64 / (self.height - 1).max(1) as f64;
            let yv = if log { 10f64.powf(yv) } else { yv };
            let _ = writeln!(out, "{yv:>9.3} |{}", row.iter().collect::<String>());
        }
        let _ = writeln!(out, "{:>10}+{}", "", "-".repeat(self.width));
        let _ = writeln!(out, "{:>11}{:<.0}{:>w$.0}", "", xmin, xmax, w = self.width - 2);
        for (si, (label, _)) in series.iter().enumerate() {
            let _ = writeln!(out, "{:>11}{} = {}", "", marks[si % marks.len()], label);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_and_contains_cells() {
        let mut t = Table::new("Demo", &["a", "long_header", "c"]);
        t.row(&["1".into(), "2".into(), "3".into()]);
        t.row(&["10".into(), "200000".into(), "x".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("long_header"));
        assert!(r.contains("200000"));
        // all body lines have equal length
        let lines: Vec<&str> = r.lines().skip(1).collect();
        let lens: Vec<usize> = lines.iter().map(|l| l.len()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["1".into()]);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a,b", "c"]);
        t.row(&["v\"q".into(), "plain".into()]);
        let csv = t.to_csv();
        assert!(csv.starts_with("\"a,b\",c\n"));
        assert!(csv.contains("\"v\"\"q\",plain"));
    }

    #[test]
    fn plot_renders_points() {
        let p = AsciiPlot::new("times");
        let s = p.render(&[
            ("cuda", vec![(200.0, 0.01), (800.0, 0.03)]),
            ("tf", vec![(200.0, 2.0), (800.0, 4.3)]),
        ]);
        assert!(s.contains("o"));
        assert!(s.contains("x"));
        assert!(s.contains("cuda"));
        assert!(s.contains("(log y)")); // 430x spread -> log scale
    }

    #[test]
    fn plot_empty_series() {
        let p = AsciiPlot::new("none");
        assert!(p.render(&[]).contains("no data"));
    }
}
