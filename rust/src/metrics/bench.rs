//! Micro-benchmark runner — the criterion stand-in for the offline build.
//!
//! Method: warmup runs, then adaptive sampling until either `max_samples`
//! is reached or the coefficient of variation drops under `cv_target`
//! (whichever first, with a floor of `min_samples`). Reports the robust
//! median plus spread. For heavyweight end-to-end cases (multi-second
//! multiclass training) callers lower the sample counts explicitly.

use super::stats::Summary;
use crate::util::fmt_secs;

#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup: usize,
    pub min_samples: usize,
    pub max_samples: usize,
    pub cv_target: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 2, min_samples: 5, max_samples: 30, cv_target: 0.05 }
    }
}

impl BenchConfig {
    /// For expensive end-to-end runs (seconds each).
    pub fn heavy() -> Self {
        BenchConfig { warmup: 1, min_samples: 3, max_samples: 5, cv_target: 0.10 }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub summary: Summary,
}

impl BenchResult {
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} median {:>10}  mean {:>10}  ±{:>5.1}%  (n={})",
            self.name,
            fmt_secs(self.summary.median),
            fmt_secs(self.summary.mean),
            self.summary.cv() * 100.0,
            self.summary.n,
        )
    }
}

/// Run `f` repeatedly and summarize wall-clock seconds per run.
pub fn bench<F: FnMut()>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.max_samples);
    while samples.len() < cfg.max_samples {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
        if samples.len() >= cfg.min_samples {
            let s = Summary::of(&samples);
            if s.cv() < cfg.cv_target {
                break;
            }
        }
    }
    BenchResult { name: name.to_string(), summary: Summary::of(&samples) }
}

/// Time a single run (for workloads too heavy to repeat).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut count = 0usize;
        let cfg = BenchConfig { warmup: 1, min_samples: 3, max_samples: 5, cv_target: 0.0 };
        let r = bench("spin", &cfg, || {
            count += 1;
            std::hint::black_box((0..1000).sum::<u64>());
        });
        // warmup + max_samples runs (cv_target 0 never met)
        assert_eq!(count, 6);
        assert_eq!(r.summary.n, 5);
        assert!(r.report_line().contains("spin"));
    }

    #[test]
    fn early_exit_on_stable_cv() {
        let cfg = BenchConfig { warmup: 0, min_samples: 3, max_samples: 100, cv_target: 10.0 };
        let r = bench("fast", &cfg, || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(r.summary.n <= 4);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, t) = time_once(|| 42);
        assert_eq!(v, 42);
        assert!(t >= 0.0);
    }
}
