//! Interconnect cost model + byte accounting.
//!
//! We account rather than sleep: every message adds `latency + bytes/bw`
//! of *simulated* seconds to the destination rank's network clock and the
//! byte counters. Reports then show both measured wall time (threads are
//! in-process, effectively free) and the simulated wire time an MPICH
//! cluster with these parameters would have spent — which is how we
//! reproduce the paper's MPI-overhead discussion without real hardware.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Latency/bandwidth parameters of the simulated interconnect.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Per-message latency in seconds (e.g. 50µs for cluster ethernet).
    pub latency: f64,
    /// Bandwidth in bytes/second (e.g. 1.25e9 for 10 GbE).
    pub bandwidth: f64,
}

impl CostModel {
    /// 10-gigabit ethernet with 50µs MPI latency — a typical small cluster
    /// of the paper's era.
    pub fn gige10() -> Self {
        CostModel { latency: 50e-6, bandwidth: 1.25e9 }
    }

    /// Zero-cost interconnect (co-located ranks, no wire at all).
    pub fn free() -> Self {
        CostModel { latency: 0.0, bandwidth: f64::INFINITY }
    }

    /// Intra-node link (shared memory / PCIe-class): ~1µs latency,
    /// 12 GB/s — the fast level solver sub-worlds live on, the way each
    /// node's GPUs sit behind the host bus in the paper's MPI-CUDA rig.
    pub fn shm() -> Self {
        CostModel { latency: 1e-6, bandwidth: 1.2e10 }
    }

    /// Simulated seconds for one message of `bytes`.
    pub fn transfer_secs(&self, bytes: usize) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }
}

/// CLI form: a preset name (`free` | `shm` | `gige10`) or explicit
/// `latency:bandwidth` in seconds and bytes/sec (e.g. `50e-6:1.25e9`).
impl std::str::FromStr for CostModel {
    type Err = String;

    fn from_str(s: &str) -> std::result::Result<CostModel, String> {
        match s {
            "free" => return Ok(CostModel::free()),
            "shm" => return Ok(CostModel::shm()),
            "gige10" => return Ok(CostModel::gige10()),
            _ => {}
        }
        let (lat, bw) = s.split_once(':').ok_or_else(|| {
            format!("bad cost model {s:?} (want free|shm|gige10 or LATENCY:BANDWIDTH)")
        })?;
        let latency: f64 = lat
            .parse()
            .map_err(|_| format!("bad latency in cost model {s:?}"))?;
        let bandwidth: f64 = bw
            .parse()
            .map_err(|_| format!("bad bandwidth in cost model {s:?}"))?;
        if latency < 0.0 || bandwidth <= 0.0 {
            return Err(format!("cost model {s:?} must have latency >= 0, bandwidth > 0"));
        }
        Ok(CostModel { latency, bandwidth })
    }
}

/// Shared network statistics (all ranks account into one instance).
#[derive(Debug, Default)]
pub struct NetStats {
    messages: AtomicU64,
    bytes: AtomicU64,
    /// Simulated wire time in nanoseconds (atomic-friendly integer).
    sim_nanos: AtomicU64,
}

impl NetStats {
    pub fn new() -> Arc<NetStats> {
        Arc::new(NetStats::default())
    }

    pub fn record(&self, bytes: usize, model: &CostModel) {
        self.messages.fetch_add(1, Ordering::Relaxed);
        self.bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        let nanos = (model.transfer_secs(bytes) * 1e9) as u64;
        self.sim_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn messages(&self) -> u64 {
        self.messages.load(Ordering::Relaxed)
    }

    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }

    /// Total simulated wire seconds summed over all messages (an upper
    /// bound on overhead — real transfers overlap).
    pub fn sim_secs(&self) -> f64 {
        self.sim_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_formula() {
        let m = CostModel { latency: 1e-3, bandwidth: 1e6 };
        assert!((m.transfer_secs(0) - 1e-3).abs() < 1e-12);
        assert!((m.transfer_secs(1_000_000) - 1.001).abs() < 1e-9);
    }

    #[test]
    fn free_model_is_free() {
        let m = CostModel::free();
        assert_eq!(m.transfer_secs(1 << 30), 0.0);
    }

    #[test]
    fn cost_model_parses_presets_and_pairs() {
        assert_eq!("free".parse::<CostModel>().unwrap(), CostModel::free());
        assert_eq!("shm".parse::<CostModel>().unwrap(), CostModel::shm());
        assert_eq!("gige10".parse::<CostModel>().unwrap(), CostModel::gige10());
        let m: CostModel = "50e-6:1.25e9".parse().unwrap();
        assert_eq!(m, CostModel::gige10());
        assert!("banana".parse::<CostModel>().is_err());
        assert!("1e-6".parse::<CostModel>().is_err());
        assert!("-1:5".parse::<CostModel>().is_err());
        assert!("0:0".parse::<CostModel>().is_err());
    }

    #[test]
    fn stats_accumulate() {
        let s = NetStats::new();
        let m = CostModel { latency: 1e-6, bandwidth: 1e9 };
        s.record(1000, &m);
        s.record(500, &m);
        assert_eq!(s.messages(), 2);
        assert_eq!(s.bytes(), 1500);
        assert!(s.sim_secs() > 0.0);
    }
}
