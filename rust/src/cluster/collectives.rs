//! MPI collectives over the p2p layer.
//!
//! Implemented exactly as a simple MPI would: root-relayed trees of sends
//! (linear fan-out — fine at the paper's scale of <=16 ranks, and the cost
//! model makes the message count visible either way).

use super::comm::Comm;
use crate::error::{Error, Result};

/// Reserved tag space for collectives (p2p user tags must stay below).
pub const TAG_BCAST: u32 = 0xC000_0001;
pub const TAG_SCATTER: u32 = 0xC000_0002;
pub const TAG_GATHER: u32 = 0xC000_0003;
pub const TAG_REDUCE: u32 = 0xC000_0004;
pub const TAG_BARRIER: u32 = 0xC000_0005;
pub const TAG_REDUCE_PAIR: u32 = 0xC000_0006;
pub const TAG_ALLGATHER: u32 = 0xC000_0007;
pub const TAG_FAULT: u32 = 0xC000_0008;

/// One rank's candidate in a MINLOC/MAXLOC-style reduction: a comparison
/// `key`, the global `index` it belongs to (`u64::MAX` = "no candidate"),
/// and an auxiliary `value` that rides along with the winner (e.g. the
/// f-entry of the selected working-set index). f64 payloads travel as raw
/// bit patterns, so the reduction is exact — no f32 rounding on the wire.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairCandidate {
    pub key: f64,
    pub index: u64,
    pub value: f64,
}

impl PairCandidate {
    pub fn new(key: f64, index: u64, value: f64) -> PairCandidate {
        PairCandidate { key, index, value }
    }

    /// The empty candidate for a max-reduction (never wins a strict join).
    pub fn none_max() -> PairCandidate {
        PairCandidate { key: f64::NEG_INFINITY, index: u64::MAX, value: 0.0 }
    }

    /// The empty candidate for a min-reduction.
    pub fn none_min() -> PairCandidate {
        PairCandidate { key: f64::INFINITY, index: u64::MAX, value: 0.0 }
    }

    fn to_words(self) -> [u64; 3] {
        [self.key.to_bits(), self.index, self.value.to_bits()]
    }

    fn from_words(w: &[u64]) -> Result<PairCandidate> {
        if w.len() != 3 {
            return Err(Error::Cluster(format!("pair candidate frame len {}", w.len())));
        }
        Ok(PairCandidate {
            key: f64::from_bits(w[0]),
            index: w[1],
            value: f64::from_bits(w[2]),
        })
    }
}

impl Comm {
    /// Broadcast `data` from `root` to every rank; returns the received
    /// buffer (root returns its own copy).
    pub fn bcast_f32s(&mut self, root: usize, data: &[f32]) -> Result<Vec<f32>> {
        if self.rank() == root {
            for dst in 0..self.size() {
                if dst != root {
                    self.send_f32s(dst, TAG_BCAST, data)?;
                }
            }
            Ok(data.to_vec())
        } else {
            self.recv_f32s(root, TAG_BCAST)
        }
    }

    /// Scatter equal-length chunks of `data` (root only) to all ranks.
    /// `data.len()` must be `size * chunk`.
    pub fn scatter_f32s(
        &mut self,
        root: usize,
        data: Option<&[f32]>,
        chunk: usize,
    ) -> Result<Vec<f32>> {
        if self.rank() == root {
            let data = data.ok_or_else(|| Error::Cluster("root must provide data".into()))?;
            if data.len() != self.size() * chunk {
                return Err(Error::Cluster(format!(
                    "scatter: data len {} != size {} * chunk {chunk}",
                    data.len(),
                    self.size()
                )));
            }
            let mut own = Vec::new();
            for dst in 0..self.size() {
                let part = &data[dst * chunk..(dst + 1) * chunk];
                if dst == root {
                    own = part.to_vec();
                } else {
                    self.send_f32s(dst, TAG_SCATTER, part)?;
                }
            }
            Ok(own)
        } else {
            self.recv_f32s(root, TAG_SCATTER)
        }
    }

    /// Gather per-rank buffers (possibly of different lengths) at `root`.
    /// Root receives `Some(vec_of_per_rank_buffers)`, others get `None`.
    pub fn gather_f32s(&mut self, root: usize, data: &[f32]) -> Result<Option<Vec<Vec<f32>>>> {
        self.gather_at(root, data, TAG_GATHER)
    }

    /// All-reduce (element-wise sum): gather at rank 0, reduce, re-broadcast.
    pub fn allreduce_sum_f32s(&mut self, data: &[f32]) -> Result<Vec<f32>> {
        let gathered = self.gather_reduce(data)?;
        if self.rank() == 0 {
            self.bcast_f32s(0, &gathered.unwrap())
        } else {
            self.recv_f32s(0, TAG_BCAST)
        }
    }

    fn gather_reduce(&mut self, data: &[f32]) -> Result<Option<Vec<f32>>> {
        if self.rank() == 0 {
            let mut acc = data.to_vec();
            for src in 1..self.size() {
                let part = self.recv_f32s(src, TAG_REDUCE)?;
                if part.len() != acc.len() {
                    return Err(Error::Cluster("allreduce length mismatch".into()));
                }
                for (a, b) in acc.iter_mut().zip(part.iter()) {
                    *a += b;
                }
            }
            Ok(Some(acc))
        } else {
            self.send_f32s(0, TAG_REDUCE, data)?;
            Ok(None)
        }
    }

    /// MAXLOC-style all-reduce: every rank contributes one
    /// [`PairCandidate`]; all ranks receive the candidate with the greatest
    /// `key`. Candidates are joined **in rank order with a strict
    /// comparison**, so ties go to the lowest rank — with contiguous
    /// ascending row shards this reproduces the first-index-wins
    /// tie-breaking of a serial ascending scan exactly.
    pub fn allreduce_max_pair(&mut self, cand: PairCandidate) -> Result<PairCandidate> {
        self.allreduce_pair(cand, |new, best| new.key > best.key)
    }

    /// MINLOC twin of [`Comm::allreduce_max_pair`] (smallest `key` wins,
    /// lowest rank on ties).
    pub fn allreduce_min_pair(&mut self, cand: PairCandidate) -> Result<PairCandidate> {
        self.allreduce_pair(cand, |new, best| new.key < best.key)
    }

    fn allreduce_pair(
        &mut self,
        cand: PairCandidate,
        better: impl Fn(&PairCandidate, &PairCandidate) -> bool,
    ) -> Result<PairCandidate> {
        if self.rank() == 0 {
            let mut best = cand;
            for src in 1..self.size() {
                let got = PairCandidate::from_words(&self.recv_u64s(src, TAG_REDUCE_PAIR)?)?;
                if better(&got, &best) {
                    best = got;
                }
            }
            let words = best.to_words();
            for dst in 1..self.size() {
                self.send_u64s(dst, TAG_REDUCE_PAIR, &words)?;
            }
            Ok(best)
        } else {
            self.send_u64s(0, TAG_REDUCE_PAIR, &cand.to_words())?;
            PairCandidate::from_words(&self.recv_u64s(0, TAG_REDUCE_PAIR)?)
        }
    }

    /// All-gather per-rank buffers (possibly of different lengths): every
    /// rank receives all ranks' buffers ordered by rank. Root-relayed like
    /// the other collectives: gather at rank 0, re-broadcast with a lengths
    /// header.
    pub fn allgather_f32s(&mut self, data: &[f32]) -> Result<Vec<Vec<f32>>> {
        let gathered = self.gather_at(0, data, TAG_ALLGATHER)?;
        let frame = if self.rank() == 0 {
            let parts = gathered.unwrap();
            let mut frame = Vec::with_capacity(1 + parts.len());
            frame.push(parts.len() as f32);
            for p in &parts {
                if p.len() >= (1 << 24) {
                    return Err(Error::Cluster(format!(
                        "allgather buffer len {} too large for f32 wire count",
                        p.len()
                    )));
                }
                frame.push(p.len() as f32);
            }
            for p in &parts {
                frame.extend_from_slice(p);
            }
            self.bcast_f32s(0, &frame)?
        } else {
            self.bcast_f32s(0, &[])?
        };
        // Decode [n_ranks, len_0.., payload_0..].
        let ranks = frame.first().map(|&v| v as usize).unwrap_or(0);
        if ranks != self.size() || frame.len() < 1 + ranks {
            return Err(Error::Cluster("allgather frame header corrupt".into()));
        }
        let mut out = Vec::with_capacity(ranks);
        let mut pos = 1 + ranks;
        for r in 0..ranks {
            let len = frame[1 + r] as usize;
            let end = pos + len;
            if end > frame.len() {
                return Err(Error::Cluster("allgather frame truncated".into()));
            }
            out.push(frame[pos..end].to_vec());
            pos = end;
        }
        if pos != frame.len() {
            return Err(Error::Cluster("allgather frame has trailing data".into()));
        }
        Ok(out)
    }

    /// u64 twin of [`Comm::allgather_f32s`] — exact integers on the wire
    /// (per-rank solver counters would silently round above 2^24 as f32).
    pub fn allgather_u64s(&mut self, data: &[u64]) -> Result<Vec<Vec<u64>>> {
        let frame = if self.rank() == 0 {
            let mut parts = vec![Vec::new(); self.size()];
            parts[0] = data.to_vec();
            for src in 1..self.size() {
                parts[src] = self.recv_u64s(src, TAG_ALLGATHER)?;
            }
            let mut frame = Vec::with_capacity(1 + parts.len());
            frame.push(parts.len() as u64);
            for p in &parts {
                frame.push(p.len() as u64);
            }
            for p in &parts {
                frame.extend_from_slice(p);
            }
            for dst in 1..self.size() {
                self.send_u64s(dst, TAG_ALLGATHER, &frame)?;
            }
            frame
        } else {
            self.send_u64s(0, TAG_ALLGATHER, data)?;
            self.recv_u64s(0, TAG_ALLGATHER)?
        };
        // Decode [n_ranks, len_0.., payload_0..].
        let ranks = frame.first().copied().unwrap_or(0) as usize;
        if ranks != self.size() || frame.len() < 1 + ranks {
            return Err(Error::Cluster("allgather frame header corrupt".into()));
        }
        let mut out = Vec::with_capacity(ranks);
        let mut pos = 1 + ranks;
        for r in 0..ranks {
            let len = frame[1 + r] as usize;
            let end = pos + len;
            if end > frame.len() {
                return Err(Error::Cluster("allgather frame truncated".into()));
            }
            out.push(frame[pos..end].to_vec());
            pos = end;
        }
        if pos != frame.len() {
            return Err(Error::Cluster("allgather frame has trailing data".into()));
        }
        Ok(out)
    }

    /// Ragged *section* all-gather — the cascade's survivor exchange.
    /// Every rank contributes zero or more sections, each a `key` (e.g. a
    /// leaf-shard index), an exact u64 `meta` frame (e.g. global row ids —
    /// f32 integers stop being exact at 2^24, far below million-row id
    /// spaces), and an f32 `payload` (e.g. packed rows/labels/alphas).
    /// Every rank receives the union of all ranks' sections stable-sorted
    /// by `key` (ties keep contributing-rank order), identical everywhere.
    ///
    /// Wire format: one u64 header frame per rank
    /// `[n_sections, (key, meta_len, payload_len, meta..)*]` plus one f32
    /// frame of concatenated payloads, exchanged through the existing
    /// root-relayed allgathers — so the traffic lands in this
    /// communicator's level ledger like any other collective.
    pub fn gather_sections(
        &mut self,
        keys: &[u64],
        meta: &[Vec<u64>],
        payload: &[Vec<f32>],
    ) -> Result<Vec<(u64, Vec<u64>, Vec<f32>)>> {
        if keys.len() != meta.len() || keys.len() != payload.len() {
            return Err(Error::Cluster(format!(
                "gather_sections: {} keys, {} meta frames, {} payloads",
                keys.len(),
                meta.len(),
                payload.len()
            )));
        }
        let meta_total: usize = meta.iter().map(|m| m.len()).sum();
        let mut head = Vec::with_capacity(1 + keys.len() * 3 + meta_total);
        head.push(keys.len() as u64);
        for ((k, m), p) in keys.iter().zip(meta).zip(payload) {
            head.push(*k);
            head.push(m.len() as u64);
            head.push(p.len() as u64);
            head.extend_from_slice(m);
        }
        let mut body = Vec::with_capacity(payload.iter().map(|p| p.len()).sum());
        for p in payload {
            body.extend_from_slice(p);
        }
        let heads = self.allgather_u64s(&head)?;
        let bodies = self.allgather_f32s(&body)?;
        let mut out = Vec::new();
        for (h, b) in heads.iter().zip(&bodies) {
            if h.is_empty() {
                return Err(Error::Cluster("section frame empty".into()));
            }
            let n = h[0] as usize;
            let mut pos = 1usize;
            let mut bpos = 0usize;
            for _ in 0..n {
                if pos + 3 > h.len() {
                    return Err(Error::Cluster("section header truncated".into()));
                }
                let key = h[pos];
                let mlen = h[pos + 1] as usize;
                let plen = h[pos + 2] as usize;
                pos += 3;
                if pos + mlen > h.len() || bpos + plen > b.len() {
                    return Err(Error::Cluster("section frame truncated".into()));
                }
                out.push((key, h[pos..pos + mlen].to_vec(), b[bpos..bpos + plen].to_vec()));
                pos += mlen;
                bpos += plen;
            }
            if pos != h.len() || bpos != b.len() {
                return Err(Error::Cluster("section frame has trailing data".into()));
            }
        }
        // Stable: equal keys keep rank order, so the result is the same
        // deterministic sequence on every rank.
        out.sort_by_key(|s| s.0);
        Ok(out)
    }

    /// Gather on an explicit tag (so collectives built on top of gather do
    /// not collide with user-level [`Comm::gather_f32s`] traffic).
    fn gather_at(&mut self, root: usize, data: &[f32], tag: u32) -> Result<Option<Vec<Vec<f32>>>> {
        if self.rank() == root {
            let mut out = vec![Vec::new(); self.size()];
            out[root] = data.to_vec();
            for src in 0..self.size() {
                if src != root {
                    out[src] = self.recv_f32s(src, tag)?;
                }
            }
            Ok(Some(out))
        } else {
            self.send_f32s(root, tag, data)?;
            Ok(None)
        }
    }

    /// Failure consensus: after a collective fails with a dead-peer
    /// signature (a fast-failing send to a dropped inbox, or a receive
    /// timeout), every survivor calls this on the SAME communicator the
    /// failure happened on, and all of them return the same list of dead
    /// ranks (comm-rank indices) — the `RankFailed(r)` verdict the
    /// recovery path re-shards around.
    ///
    /// Two phases, all on [`TAG_FAULT`]:
    /// 1. *Probe*: send an alive-probe to every peer, then receive one
    ///    from each. A failed send (inbox gone) is death evidence now; a
    ///    probe that never arrives is death evidence after the timeout.
    /// 2. *Union*: exchange suspicion masks with every believed-alive
    ///    peer and take the union, so survivors that never talked to the
    ///    dead rank directly (e.g. non-roots of a root-relayed collective
    ///    that only saw the root go quiet) still agree on WHO died.
    ///
    /// Probes run under a doubled receive timeout: survivors enter
    /// consensus up to one full timeout apart (the root detects a dead
    /// send instantly, non-roots only when their relay receive expires),
    /// and a live peer must not be condemned for that skew. Assumes
    /// fail-stop ranks (dead or responsive — what [`super::FaultPlan`]
    /// scripts); a rank that is merely slower than 2x the timeout is
    /// indistinguishable from dead, as in any timeout-based detector.
    pub fn failure_consensus(&mut self) -> Result<Vec<usize>> {
        let me = self.rank();
        let saved = self.recv_timeout();
        self.set_recv_timeout(saved * 2);
        let verdict = self.failure_consensus_inner(me);
        self.set_recv_timeout(saved);
        let suspect = verdict?;
        if suspect[me] {
            return Err(Error::Cluster(format!(
                "rank {me}: survivors declared this rank dead (partitioned world)"
            )));
        }
        Ok((0..self.size()).filter(|&r| suspect[r]).collect())
    }

    fn failure_consensus_inner(&mut self, me: usize) -> Result<Vec<bool>> {
        let mut suspect = vec![false; self.size()];
        for dst in 0..self.size() {
            if dst != me && self.send(dst, TAG_FAULT, vec![1]).is_err() {
                suspect[dst] = true;
            }
        }
        for src in 0..self.size() {
            if src != me && !suspect[src] && self.recv(src, TAG_FAULT).is_err() {
                suspect[src] = true;
            }
        }
        // Per-sender FIFO ordering means a peer's phase-1 probe is always
        // matched before its phase-2 mask, even when the peer races ahead.
        let mine: Vec<u64> =
            (0..self.size()).filter(|&r| suspect[r]).map(|r| r as u64).collect();
        for dst in 0..self.size() {
            if dst != me && !suspect[dst] {
                // A failed mask send is re-classified by the recv below.
                let _ = self.send_u64s(dst, TAG_FAULT, &mine);
            }
        }
        for src in 0..self.size() {
            if src == me || suspect[src] {
                continue;
            }
            match self.recv_u64s(src, TAG_FAULT) {
                Ok(mask) => {
                    for r in mask {
                        if (r as usize) < self.size() {
                            suspect[r as usize] = true;
                        }
                    }
                }
                Err(_) => suspect[src] = true,
            }
        }
        Ok(suspect)
    }

    /// Barrier: empty gather + empty bcast.
    pub fn barrier(&mut self) -> Result<()> {
        if self.rank() == 0 {
            for src in 1..self.size() {
                self.recv(src, TAG_BARRIER)?;
            }
            for dst in 1..self.size() {
                self.send(dst, TAG_BARRIER, Vec::new())?;
            }
        } else {
            self.send(0, TAG_BARRIER, Vec::new())?;
            self.recv(0, TAG_BARRIER)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::PairCandidate;
    use crate::cluster::{CostModel, Universe};

    #[test]
    fn max_pair_picks_global_argmax() {
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            // keys 0,10,20,30 at indices 100+rank; aux value = -key
            let k = (c.rank() * 10) as f64;
            let cand = PairCandidate::new(k, 100 + c.rank() as u64, -k);
            c.allreduce_max_pair(cand).unwrap()
        });
        for v in out {
            assert_eq!(v, PairCandidate::new(30.0, 103, -30.0));
        }
    }

    #[test]
    fn min_pair_picks_global_argmin() {
        let out = Universe::new(3, CostModel::free()).run(|mut c| {
            let k = (c.rank() as f64) - 1.0; // -1, 0, 1
            c.allreduce_min_pair(PairCandidate::new(k, c.rank() as u64, 2.0 * k)).unwrap()
        });
        for v in out {
            assert_eq!(v, PairCandidate::new(-1.0, 0, -2.0));
        }
    }

    #[test]
    fn pair_ties_go_to_lowest_rank() {
        // Equal keys everywhere: the strict rank-order join must keep rank
        // 0's candidate, matching a serial ascending scan's first-win.
        let out = Universe::new(5, CostModel::free()).run(|mut c| {
            let cand = PairCandidate::new(7.0, c.rank() as u64, c.rank() as f64);
            c.allreduce_max_pair(cand).unwrap()
        });
        for v in out {
            assert_eq!(v.index, 0);
            assert_eq!(v.value, 0.0);
        }
    }

    #[test]
    fn pair_empty_candidates_never_win() {
        let out = Universe::new(3, CostModel::free()).run(|mut c| {
            let cand = if c.rank() == 1 {
                PairCandidate::new(-5.0, 42, 9.0)
            } else {
                PairCandidate::none_max()
            };
            c.allreduce_max_pair(cand).unwrap()
        });
        for v in out {
            assert_eq!((v.index, v.value), (42, 9.0));
        }
        // All empty: the reduction reports "no candidate" to everyone.
        let out = Universe::new(3, CostModel::free())
            .run(|mut c| c.allreduce_min_pair(PairCandidate::none_min()).unwrap());
        for v in out {
            assert_eq!(v.index, u64::MAX);
        }
    }

    #[test]
    fn pair_payload_is_bit_exact() {
        // f64 keys/values must survive the wire without f32 rounding.
        let key = 1.0 + 1e-12;
        let out = Universe::new(2, CostModel::free()).run(move |mut c| {
            let cand = PairCandidate::new(key * (1.0 + c.rank() as f64), c.rank() as u64, key);
            c.allreduce_max_pair(cand).unwrap()
        });
        for v in out {
            assert_eq!(v.key.to_bits(), (key * 2.0).to_bits());
            assert_eq!(v.value.to_bits(), key.to_bits());
        }
    }

    #[test]
    fn allgather_delivers_all_ragged_buffers_everywhere() {
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            let mine = vec![c.rank() as f32; c.rank() + 1];
            c.allgather_f32s(&mine).unwrap()
        });
        for per_rank in out {
            assert_eq!(per_rank.len(), 4);
            for (r, buf) in per_rank.iter().enumerate() {
                assert_eq!(buf, &vec![r as f32; r + 1]);
            }
        }
    }

    #[test]
    fn allgather_u64s_is_exact_beyond_f32_range() {
        // Counters above 2^24 (where f32 integers stop being exact) and a
        // full-range u64 must survive the wire bit-for-bit.
        let big = [u64::MAX, (1u64 << 24) + 1, 0];
        let out = Universe::new(3, CostModel::free()).run(move |mut c| {
            let mine = [big[c.rank()], c.rank() as u64];
            c.allgather_u64s(&mine).unwrap()
        });
        for per_rank in out {
            assert_eq!(per_rank.len(), 3);
            for (r, buf) in per_rank.iter().enumerate() {
                assert_eq!(buf, &vec![big[r], r as u64]);
            }
        }
    }

    #[test]
    fn gather_sections_unions_ragged_sections_sorted_by_key() {
        // Rank r contributes r sections (rank 0 contributes none — an
        // empty contribution must not desynchronize the collective) with
        // interleaved keys, ragged meta/payload lengths, and ids beyond
        // the f32-exact range.
        let out = Universe::new(3, CostModel::free()).run(|mut c| {
            let r = c.rank();
            let mut keys = Vec::new();
            let mut meta = Vec::new();
            let mut payload = Vec::new();
            for s in 0..r {
                keys.push((10 * s + r) as u64);
                meta.push(vec![(1u64 << 40) + (r * 10 + s) as u64; s + 1]);
                payload.push(vec![r as f32 + s as f32 * 0.5; 2 * s + 1]);
            }
            c.gather_sections(&keys, &meta, &payload).unwrap()
        });
        for sections in out {
            // rank 1: key 1; rank 2: keys 2, 12 -> sorted [1, 2, 12].
            assert_eq!(sections.iter().map(|s| s.0).collect::<Vec<_>>(), vec![1, 2, 12]);
            assert_eq!(sections[0].1, vec![(1u64 << 40) + 10]);
            assert_eq!(sections[0].2, vec![1.0]);
            assert_eq!(sections[1].1, vec![(1u64 << 40) + 20]);
            assert_eq!(sections[1].2, vec![2.0]);
            assert_eq!(sections[2].1, vec![(1u64 << 40) + 21; 2]);
            assert_eq!(sections[2].2, vec![2.5; 3]);
        }
    }

    #[test]
    fn gather_sections_is_identical_on_every_rank_and_ties_keep_rank_order() {
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            // Every rank contributes one section under the SAME key; the
            // stable sort must keep contributing-rank order.
            let keys = [7u64];
            let meta = vec![vec![c.rank() as u64]];
            let payload = vec![vec![c.rank() as f32]];
            c.gather_sections(&keys, &meta, &payload).unwrap()
        });
        let first = &out[0];
        assert_eq!(first.len(), 4);
        for (r, s) in first.iter().enumerate() {
            assert_eq!((s.0, s.1[0], s.2[0]), (7, r as u64, r as f32));
        }
        for sections in &out[1..] {
            assert_eq!(sections, first, "all ranks must hold the same union");
        }
    }

    #[test]
    fn gather_sections_payloads_are_bit_exact_and_may_be_empty() {
        let out = Universe::new(2, CostModel::free()).run(|mut c| {
            if c.rank() == 0 {
                // A section with an empty payload (all-zero survivor set)
                // still travels.
                c.gather_sections(&[3], &[vec![9]], &[Vec::new()]).unwrap()
            } else {
                c.gather_sections(&[1], &[vec![4]], &[vec![1.0 + f32::EPSILON]]).unwrap()
            }
        });
        for sections in out {
            assert_eq!(sections.len(), 2);
            assert_eq!((sections[0].0, sections[0].2.len()), (1, 1));
            assert_eq!(sections[0].2[0].to_bits(), (1.0f32 + f32::EPSILON).to_bits());
            assert_eq!((sections[1].0, sections[1].1[0], sections[1].2.len()), (3, 9, 0));
        }
    }

    #[test]
    fn gather_sections_rejects_mismatched_inputs() {
        Universe::new(1, CostModel::free()).run(|mut c| {
            assert!(c.gather_sections(&[1, 2], &[vec![0]], &[vec![0.0]]).is_err());
        });
    }

    #[test]
    fn gather_sections_accounts_wire_traffic() {
        let u = Universe::new(2, CostModel::gige10());
        let stats = u.stats();
        u.run(|mut c| {
            let keys = [c.rank() as u64];
            let meta = vec![vec![0u64; 4]];
            let payload = vec![vec![0.0f32; 8]];
            c.gather_sections(&keys, &meta, &payload).unwrap();
        });
        assert!(stats.bytes() > 0, "survivor gather must land in the ledger");
    }

    #[test]
    fn allgather_single_rank_is_identity() {
        let out = Universe::new(1, CostModel::free())
            .run(|mut c| c.allgather_f32s(&[1.5, -2.0]).unwrap());
        assert_eq!(out[0], vec![vec![1.5, -2.0]]);
    }

    #[test]
    fn bcast_reaches_all_ranks() {
        let out = Universe::new(4, CostModel::free())
            .run(|mut c| c.bcast_f32s(1, &[3.0, 4.0]).unwrap());
        for v in out {
            assert_eq!(v, vec![3.0, 4.0]);
        }
    }

    #[test]
    fn scatter_partitions() {
        let out = Universe::new(3, CostModel::free()).run(|mut c| {
            let data: Vec<f32> = (0..6).map(|v| v as f32).collect();
            let root_data = if c.rank() == 0 { Some(&data[..]) } else { None };
            c.scatter_f32s(0, root_data, 2).unwrap()
        });
        assert_eq!(out[0], vec![0.0, 1.0]);
        assert_eq!(out[1], vec![2.0, 3.0]);
        assert_eq!(out[2], vec![4.0, 5.0]);
    }

    #[test]
    fn gather_collects_ragged_buffers() {
        let out = Universe::new(3, CostModel::free()).run(|mut c| {
            let mine = vec![c.rank() as f32; c.rank() + 1]; // ragged lengths
            c.gather_f32s(0, &mine).unwrap()
        });
        let root = out[0].as_ref().unwrap();
        assert_eq!(root[0], vec![0.0]);
        assert_eq!(root[1], vec![1.0, 1.0]);
        assert_eq!(root[2], vec![2.0, 2.0, 2.0]);
        assert!(out[1].is_none() && out[2].is_none());
    }

    #[test]
    fn allreduce_equals_sequential_reduce() {
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            let mine = vec![c.rank() as f32, 1.0];
            c.allreduce_sum_f32s(&mine).unwrap()
        });
        for v in out {
            assert_eq!(v, vec![6.0, 4.0]); // 0+1+2+3, 1*4
        }
    }

    #[test]
    fn barrier_completes() {
        // If the barrier deadlocked this test would hit the 30s recv timeout.
        let out = Universe::new(5, CostModel::free()).run(|mut c| {
            for _ in 0..3 {
                c.barrier().unwrap();
            }
            true
        });
        assert!(out.iter().all(|&v| v));
    }

    #[test]
    fn scatter_length_mismatch_rejected() {
        Universe::new(2, CostModel::free()).run(|mut c| {
            if c.rank() == 0 {
                let data = vec![0.0f32; 3]; // not 2*chunk
                assert!(c.scatter_f32s(0, Some(&data), 2).is_err());
                // unblock rank 1 with a real scatter
                let ok = vec![0.0f32; 4];
                c.scatter_f32s(0, Some(&ok), 2).unwrap();
            } else {
                c.scatter_f32s(0, None, 2).unwrap();
            }
        });
    }

    #[test]
    fn split_groups_preserve_pair_tie_breaking() {
        // Equal keys inside each split group: the strict rank-order join
        // must pick the lowest *sub*-rank, which with `key = parent rank`
        // is the lowest parent rank of the group — the same contiguous
        // first-index-wins order the distributed solver relies on.
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            let mut sub = c.split(c.rank() / 2, c.rank()).unwrap();
            let cand = PairCandidate::new(1.0, c.rank() as u64, c.rank() as f64);
            sub.allreduce_max_pair(cand).unwrap()
        });
        assert_eq!(out[0].index, 0);
        assert_eq!(out[1].index, 0);
        assert_eq!(out[2].index, 2);
        assert_eq!(out[3].index, 2);
    }

    #[test]
    fn split_reversed_keys_flip_tie_winner() {
        // The split key really orders the group: reversed keys make the
        // highest parent rank sub-rank 0, so it now wins every tie.
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            let mut sub = c.split(0, c.size() - c.rank()).unwrap();
            let cand = PairCandidate::new(7.0, c.rank() as u64, 0.0);
            sub.allreduce_min_pair(cand).unwrap()
        });
        for v in out {
            assert_eq!(v.index, 3);
        }
    }

    #[test]
    fn collectives_work_on_derived_comms() {
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            let mut sub = c.split(c.rank() % 2, c.rank()).unwrap();
            sub.barrier().unwrap();
            let sum = sub.allreduce_sum_f32s(&[c.rank() as f32]).unwrap()[0];
            let gathered = sub.allgather_f32s(&[c.rank() as f32]).unwrap();
            (sum, gathered)
        });
        // Even group {0,2} sums to 2, odd group {1,3} to 4; allgather
        // returns the group's payloads in sub-rank order.
        assert_eq!(out[0].0, 2.0);
        assert_eq!(out[1].0, 4.0);
        assert_eq!(out[0].1, vec![vec![0.0], vec![2.0]]);
        assert_eq!(out[3].1, vec![vec![1.0], vec![3.0]]);
    }

    #[test]
    fn collective_byte_accounting() {
        let u = Universe::new(4, CostModel::gige10());
        let stats = u.stats();
        u.run(|mut c| {
            c.bcast_f32s(0, &[0.0; 256]).unwrap();
        });
        // root sends 3 messages of 1 KiB
        assert_eq!(stats.messages(), 3);
        assert_eq!(stats.bytes(), 3 * 1024);
    }

    #[test]
    fn failure_consensus_agrees_on_the_dead_rank() {
        use std::time::Duration;
        // Rank 2 dies before the round; every survivor must converge on
        // the same verdict, including ranks that would not have noticed
        // the death directly.
        let out = Universe::new(4, CostModel::free())
            .with_recv_timeout(Duration::from_millis(100))
            .run(|mut c| {
                if c.rank() == 2 {
                    return vec![usize::MAX];
                }
                c.failure_consensus().unwrap()
            });
        for r in [0, 1, 3] {
            assert_eq!(out[r], vec![2], "rank {r} verdict");
        }
    }

    #[test]
    fn failure_consensus_with_all_ranks_alive_is_empty() {
        let out = Universe::new(3, CostModel::free()).run(|mut c| c.failure_consensus().unwrap());
        for v in out {
            assert!(v.is_empty());
        }
    }

    #[test]
    fn failure_consensus_handles_multiple_dead_ranks() {
        use std::time::Duration;
        let out = Universe::new(5, CostModel::free())
            .with_recv_timeout(Duration::from_millis(100))
            .run(|mut c| {
                if c.rank() == 1 || c.rank() == 3 {
                    return vec![usize::MAX];
                }
                c.failure_consensus().unwrap()
            });
        for r in [0, 2, 4] {
            assert_eq!(out[r], vec![1, 3], "rank {r} verdict");
        }
    }

    #[test]
    fn failure_consensus_tolerates_a_merely_slow_rank() {
        use std::time::Duration;
        // Rank 1 is late to the round by well under the doubled probe
        // horizon: nobody may condemn it.
        let out = Universe::new(3, CostModel::free())
            .with_recv_timeout(Duration::from_millis(200))
            .run(|mut c| {
                if c.rank() == 1 {
                    std::thread::sleep(Duration::from_millis(80));
                }
                c.failure_consensus().unwrap()
            });
        for v in out {
            assert!(v.is_empty(), "slow is not dead: {v:?}");
        }
    }
}
