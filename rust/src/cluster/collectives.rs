//! MPI collectives over the p2p layer.
//!
//! Implemented exactly as a simple MPI would: root-relayed trees of sends
//! (linear fan-out — fine at the paper's scale of <=16 ranks, and the cost
//! model makes the message count visible either way).

use super::comm::Comm;
use crate::error::{Error, Result};

/// Reserved tag space for collectives (p2p user tags must stay below).
pub const TAG_BCAST: u32 = 0xC000_0001;
pub const TAG_SCATTER: u32 = 0xC000_0002;
pub const TAG_GATHER: u32 = 0xC000_0003;
pub const TAG_REDUCE: u32 = 0xC000_0004;
pub const TAG_BARRIER: u32 = 0xC000_0005;

impl Comm {
    /// Broadcast `data` from `root` to every rank; returns the received
    /// buffer (root returns its own copy).
    pub fn bcast_f32s(&mut self, root: usize, data: &[f32]) -> Result<Vec<f32>> {
        if self.rank() == root {
            for dst in 0..self.size() {
                if dst != root {
                    self.send_f32s(dst, TAG_BCAST, data)?;
                }
            }
            Ok(data.to_vec())
        } else {
            self.recv_f32s(root, TAG_BCAST)
        }
    }

    /// Scatter equal-length chunks of `data` (root only) to all ranks.
    /// `data.len()` must be `size * chunk`.
    pub fn scatter_f32s(&mut self, root: usize, data: Option<&[f32]>, chunk: usize) -> Result<Vec<f32>> {
        if self.rank() == root {
            let data = data.ok_or_else(|| Error::Cluster("root must provide data".into()))?;
            if data.len() != self.size() * chunk {
                return Err(Error::Cluster(format!(
                    "scatter: data len {} != size {} * chunk {chunk}",
                    data.len(),
                    self.size()
                )));
            }
            let mut own = Vec::new();
            for dst in 0..self.size() {
                let part = &data[dst * chunk..(dst + 1) * chunk];
                if dst == root {
                    own = part.to_vec();
                } else {
                    self.send_f32s(dst, TAG_SCATTER, part)?;
                }
            }
            Ok(own)
        } else {
            self.recv_f32s(root, TAG_SCATTER)
        }
    }

    /// Gather per-rank buffers (possibly of different lengths) at `root`.
    /// Root receives `Some(vec_of_per_rank_buffers)`, others get `None`.
    pub fn gather_f32s(&mut self, root: usize, data: &[f32]) -> Result<Option<Vec<Vec<f32>>>> {
        if self.rank() == root {
            let mut out = vec![Vec::new(); self.size()];
            out[root] = data.to_vec();
            for src in 0..self.size() {
                if src != root {
                    out[src] = self.recv_f32s(src, TAG_GATHER)?;
                }
            }
            Ok(Some(out))
        } else {
            self.send_f32s(root, TAG_GATHER, data)?;
            Ok(None)
        }
    }

    /// All-reduce (element-wise sum): gather at rank 0, reduce, re-broadcast.
    pub fn allreduce_sum_f32s(&mut self, data: &[f32]) -> Result<Vec<f32>> {
        let gathered = self.gather_reduce(data)?;
        if self.rank() == 0 {
            self.bcast_f32s(0, &gathered.unwrap())
        } else {
            self.recv_f32s(0, TAG_BCAST)
        }
    }

    fn gather_reduce(&mut self, data: &[f32]) -> Result<Option<Vec<f32>>> {
        if self.rank() == 0 {
            let mut acc = data.to_vec();
            for src in 1..self.size() {
                let part = self.recv_f32s(src, TAG_REDUCE)?;
                if part.len() != acc.len() {
                    return Err(Error::Cluster("allreduce length mismatch".into()));
                }
                for (a, b) in acc.iter_mut().zip(part.iter()) {
                    *a += b;
                }
            }
            Ok(Some(acc))
        } else {
            self.send_f32s(0, TAG_REDUCE, data)?;
            Ok(None)
        }
    }

    /// Barrier: empty gather + empty bcast.
    pub fn barrier(&mut self) -> Result<()> {
        if self.rank() == 0 {
            for src in 1..self.size() {
                self.recv(src, TAG_BARRIER)?;
            }
            for dst in 1..self.size() {
                self.send(dst, TAG_BARRIER, Vec::new())?;
            }
        } else {
            self.send(0, TAG_BARRIER, Vec::new())?;
            self.recv(0, TAG_BARRIER)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use crate::cluster::{CostModel, Universe};

    #[test]
    fn bcast_reaches_all_ranks() {
        let out = Universe::new(4, CostModel::free())
            .run(|mut c| c.bcast_f32s(1, &[3.0, 4.0]).unwrap());
        for v in out {
            assert_eq!(v, vec![3.0, 4.0]);
        }
    }

    #[test]
    fn scatter_partitions() {
        let out = Universe::new(3, CostModel::free()).run(|mut c| {
            let data: Vec<f32> = (0..6).map(|v| v as f32).collect();
            let root_data = if c.rank() == 0 { Some(&data[..]) } else { None };
            c.scatter_f32s(0, root_data, 2).unwrap()
        });
        assert_eq!(out[0], vec![0.0, 1.0]);
        assert_eq!(out[1], vec![2.0, 3.0]);
        assert_eq!(out[2], vec![4.0, 5.0]);
    }

    #[test]
    fn gather_collects_ragged_buffers() {
        let out = Universe::new(3, CostModel::free()).run(|mut c| {
            let mine = vec![c.rank() as f32; c.rank() + 1]; // ragged lengths
            c.gather_f32s(0, &mine).unwrap()
        });
        let root = out[0].as_ref().unwrap();
        assert_eq!(root[0], vec![0.0]);
        assert_eq!(root[1], vec![1.0, 1.0]);
        assert_eq!(root[2], vec![2.0, 2.0, 2.0]);
        assert!(out[1].is_none() && out[2].is_none());
    }

    #[test]
    fn allreduce_equals_sequential_reduce() {
        let out = Universe::new(4, CostModel::free()).run(|mut c| {
            let mine = vec![c.rank() as f32, 1.0];
            c.allreduce_sum_f32s(&mine).unwrap()
        });
        for v in out {
            assert_eq!(v, vec![6.0, 4.0]); // 0+1+2+3, 1*4
        }
    }

    #[test]
    fn barrier_completes() {
        // If the barrier deadlocked this test would hit the 30s recv timeout.
        let out = Universe::new(5, CostModel::free()).run(|mut c| {
            for _ in 0..3 {
                c.barrier().unwrap();
            }
            true
        });
        assert!(out.iter().all(|&v| v));
    }

    #[test]
    fn scatter_length_mismatch_rejected() {
        Universe::new(2, CostModel::free()).run(|mut c| {
            if c.rank() == 0 {
                let data = vec![0.0f32; 3]; // not 2*chunk
                assert!(c.scatter_f32s(0, Some(&data), 2).is_err());
                // unblock rank 1 with a real scatter
                let ok = vec![0.0f32; 4];
                c.scatter_f32s(0, Some(&ok), 2).unwrap();
            } else {
                c.scatter_f32s(0, None, 2).unwrap();
            }
        });
    }

    #[test]
    fn collective_byte_accounting() {
        let u = Universe::new(4, CostModel::gige10());
        let stats = u.stats();
        u.run(|mut c| {
            c.bcast_f32s(0, &[0.0; 256]).unwrap();
        });
        // root sends 3 messages of 1 KiB
        assert_eq!(stats.messages(), 3);
        assert_eq!(stats.bytes(), 3 * 1024);
    }
}
