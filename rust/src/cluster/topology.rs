//! Topology: the level structure of the simulated machine, with one cost
//! model and one traffic ledger per level.
//!
//! The paper's hybrid rig is a two-level machine — MPICH ranks across
//! nodes, CUDA parallelism inside each node — and its Table IV overhead
//! story only splits cleanly if the two links are priced and measured
//! separately. A [`Topology`] captures exactly that: an ordered list of
//! [`Level`]s (outermost first, e.g. `inter` = worker world over cluster
//! ethernet, `intra` = solver sub-worlds over the node-local bus), each
//! with its own [`CostModel`] and [`NetStats`]. [`Topology::universe`]
//! spawns the world (total ranks = product of level sizes) wired to the
//! outermost level; the SPMD body then derives the inner levels with
//! [`super::Comm::split_with`], handing each derived communicator its
//! level's model and ledger.
//!
//! [`Topology::net`] snapshots the per-level ledgers as a [`NetReport`] —
//! the structured per-level/rolled-up view every report above the cluster
//! layer (solver outcomes, multiclass reports, bench rows) now carries.

use std::sync::Arc;

use super::costmodel::{CostModel, NetStats};
use super::universe::Universe;

/// Canonical name of the outer (cross-node) level.
pub const LEVEL_INTER: &str = "inter";
/// Canonical name of the inner (node-local solver sub-world) level.
pub const LEVEL_INTRA: &str = "intra";

/// One level of the machine: how many ranks it multiplies into the world
/// and how its link is priced.
#[derive(Debug, Clone)]
pub struct Level {
    pub name: String,
    pub ranks: usize,
    pub cost: CostModel,
}

/// The level structure of a run (outermost level first).
#[derive(Clone)]
pub struct Topology {
    levels: Vec<Level>,
    stats: Vec<Arc<NetStats>>,
}

impl Topology {
    pub fn new(levels: Vec<Level>) -> Topology {
        assert!(!levels.is_empty(), "topology needs at least one level");
        assert!(
            levels.iter().all(|l| l.ranks > 0),
            "every topology level needs at least one rank"
        );
        let stats = levels.iter().map(|_| NetStats::new()).collect();
        Topology { levels, stats }
    }

    /// One named level (a standalone sub-world, e.g. the distributed
    /// engine solving outside any worker hierarchy).
    pub fn single(name: &str, ranks: usize, cost: CostModel) -> Topology {
        Topology::new(vec![Level { name: name.into(), ranks, cost }])
    }

    /// The flat PR-2-style world: one `inter` level of `ranks` workers.
    pub fn flat(ranks: usize, cost: CostModel) -> Topology {
        Topology::single(LEVEL_INTER, ranks, cost)
    }

    /// The paper's two-level machine: `workers` nodes on the `inter` link,
    /// each carrying a `solver_ranks`-wide sub-world on the `intra` link.
    pub fn two_level(
        workers: usize,
        inter: CostModel,
        solver_ranks: usize,
        intra: CostModel,
    ) -> Topology {
        Topology::new(vec![
            Level { name: LEVEL_INTER.into(), ranks: workers, cost: inter },
            Level { name: LEVEL_INTRA.into(), ranks: solver_ranks, cost: intra },
        ])
    }

    pub fn levels(&self) -> &[Level] {
        &self.levels
    }

    /// Total world size: the product of the level sizes.
    pub fn total_ranks(&self) -> usize {
        self.levels.iter().map(|l| l.ranks).product()
    }

    /// The traffic ledger of level `i` (0 = outermost). Hand this to
    /// [`super::Comm::split_with`] so a derived communicator accounts
    /// into its level.
    pub fn level_stats(&self, i: usize) -> Arc<NetStats> {
        Arc::clone(&self.stats[i])
    }

    /// Spawn the world: `total_ranks()` rank threads whose world
    /// communicator is priced and accounted at the outermost level.
    pub fn universe(&self) -> Universe {
        Universe::with_stats(self.total_ranks(), self.levels[0].cost, self.level_stats(0))
    }

    /// Snapshot every level's ledger.
    pub fn net(&self) -> NetReport {
        NetReport {
            levels: self
                .levels
                .iter()
                .zip(self.stats.iter())
                .map(|(l, s)| LevelNet::snapshot(&l.name, s))
                .collect(),
        }
    }
}

/// One level's traffic totals at a point in time.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelNet {
    pub level: String,
    pub messages: u64,
    pub bytes: u64,
    /// Simulated wire seconds under the level's cost model.
    pub sim_secs: f64,
}

impl LevelNet {
    pub fn snapshot(name: &str, stats: &NetStats) -> LevelNet {
        LevelNet {
            level: name.into(),
            messages: stats.messages(),
            bytes: stats.bytes(),
            sim_secs: stats.sim_secs(),
        }
    }
}

/// Interconnect traffic split by topology level, with roll-up accessors.
/// The invariant every consumer relies on (and the property tests pin
/// down): the roll-up equals what one flat world-wide [`NetStats`] would
/// have recorded for the same message stream.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct NetReport {
    pub levels: Vec<LevelNet>,
}

impl NetReport {
    /// No traffic at all (single-host engines).
    pub fn none() -> NetReport {
        NetReport::default()
    }

    pub fn level(&self, name: &str) -> Option<&LevelNet> {
        self.levels.iter().find(|l| l.level == name)
    }

    /// Rolled-up message count across levels.
    pub fn messages(&self) -> u64 {
        self.levels.iter().map(|l| l.messages).sum()
    }

    /// Rolled-up bytes across levels.
    pub fn bytes(&self) -> u64 {
        self.levels.iter().map(|l| l.bytes).sum()
    }

    /// Rolled-up simulated wire seconds across levels.
    pub fn sim_secs(&self) -> f64 {
        self.levels.iter().map(|l| l.sim_secs).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_level_shape() {
        let t = Topology::two_level(3, CostModel::gige10(), 2, CostModel::shm());
        assert_eq!(t.total_ranks(), 6);
        assert_eq!(t.levels().len(), 2);
        assert_eq!(t.levels()[0].name, LEVEL_INTER);
        assert_eq!(t.levels()[1].name, LEVEL_INTRA);
        assert_eq!(t.universe().size(), 6);
        let net = t.net();
        assert_eq!(net.levels.len(), 2);
        assert_eq!(net.bytes(), 0);
    }

    #[test]
    fn flat_is_a_single_inter_level() {
        let t = Topology::flat(4, CostModel::free());
        assert_eq!(t.total_ranks(), 4);
        assert_eq!(t.levels()[0].name, LEVEL_INTER);
        assert!(t.net().level(LEVEL_INTRA).is_none());
    }

    #[test]
    #[should_panic]
    fn zero_rank_level_rejected() {
        Topology::two_level(2, CostModel::free(), 0, CostModel::free());
    }

    #[test]
    fn per_level_ledgers_roll_up_to_flat_totals() {
        // Recording a message stream split across levels must total
        // exactly what one flat ledger records for the same stream.
        let t = Topology::two_level(2, CostModel::gige10(), 2, CostModel::shm());
        let flat = NetStats::new();
        let sizes = [10usize, 400, 3, 77, 1024, 0];
        for (i, &b) in sizes.iter().enumerate() {
            let lvl = i % 2;
            t.level_stats(lvl).record(b, &t.levels()[lvl].cost);
            flat.record(b, &t.levels()[lvl].cost);
        }
        let net = t.net();
        assert_eq!(net.messages(), flat.messages());
        assert_eq!(net.bytes(), flat.bytes());
        assert!((net.sim_secs() - flat.sim_secs()).abs() < 1e-12);
        // And the split is genuinely per level.
        assert_eq!(net.level(LEVEL_INTER).unwrap().messages, 3);
        assert_eq!(net.level(LEVEL_INTRA).unwrap().messages, 3);
    }

    #[test]
    fn universe_traffic_lands_in_level_zero() {
        let t = Topology::two_level(2, CostModel::gige10(), 1, CostModel::shm());
        t.universe().run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 0, &[1.0, 2.0]).unwrap();
            } else {
                comm.recv_f32s(0, 0).unwrap();
            }
        });
        let net = t.net();
        assert_eq!(net.level(LEVEL_INTER).unwrap().bytes, 8);
        assert_eq!(net.level(LEVEL_INTRA).unwrap().bytes, 0);
        assert_eq!(net.bytes(), 8);
    }
}
