//! Point-to-point communicator (rank handle).
//!
//! Each rank owns an mpsc receiver; senders to every rank are shared.
//! Messages carry (src, tag, payload). `recv` matches on (src, tag) and
//! buffers out-of-order arrivals locally, like an MPI unexpected-message
//! queue.

use std::collections::VecDeque;
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Duration;

use super::costmodel::{CostModel, NetStats};
use crate::error::{Error, Result};

/// Message envelope on the simulated wire.
#[derive(Debug)]
pub struct Envelope {
    pub src: usize,
    pub tag: u32,
    pub payload: Vec<u8>,
}

/// Per-rank communicator handle.
pub struct Comm {
    rank: usize,
    size: usize,
    senders: Vec<Sender<Envelope>>,
    inbox: Receiver<Envelope>,
    /// Unexpected-message queue (arrived before being asked for).
    pending: VecDeque<Envelope>,
    stats: Arc<NetStats>,
    model: CostModel,
    recv_timeout: Duration,
}

impl Comm {
    #[allow(clippy::too_many_arguments)]
    pub(super) fn new(
        rank: usize,
        size: usize,
        senders: Vec<Sender<Envelope>>,
        inbox: Receiver<Envelope>,
        stats: Arc<NetStats>,
        model: CostModel,
    ) -> Comm {
        Comm {
            rank,
            size,
            senders,
            inbox,
            pending: VecDeque::new(),
            stats,
            model,
            recv_timeout: Duration::from_secs(30),
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    pub fn model(&self) -> CostModel {
        self.model
    }

    /// Override the receive timeout (default 30s). Failure-injection tests
    /// use short timeouts to exercise the deadlock-detection path.
    pub fn set_recv_timeout(&mut self, timeout: Duration) {
        self.recv_timeout = timeout;
    }

    /// Send raw bytes to `dst` with a tag. Self-sends are allowed (loopback)
    /// and accounted at zero cost.
    pub fn send(&self, dst: usize, tag: u32, payload: Vec<u8>) -> Result<()> {
        if dst >= self.size {
            return Err(Error::Cluster(format!("send to invalid rank {dst}")));
        }
        if dst != self.rank {
            self.stats.record(payload.len(), &self.model);
        }
        self.senders[dst]
            .send(Envelope { src: self.rank, tag, payload })
            .map_err(|_| Error::Cluster(format!("rank {dst} hung up")))
    }

    /// Receive the next message matching (src, tag), buffering others.
    pub fn recv(&mut self, src: usize, tag: u32) -> Result<Vec<u8>> {
        // Check the unexpected-message queue first.
        if let Some(pos) = self.pending.iter().position(|e| e.src == src && e.tag == tag) {
            return Ok(self.pending.remove(pos).unwrap().payload);
        }
        loop {
            let env = self
                .inbox
                .recv_timeout(self.recv_timeout)
                .map_err(|_| {
                    Error::Cluster(format!(
                        "rank {}: timeout waiting for (src={src}, tag={tag})",
                        self.rank
                    ))
                })?;
            if env.src == src && env.tag == tag {
                return Ok(env.payload);
            }
            self.pending.push_back(env);
        }
    }

    // ---- typed helpers (f32/u64 slices in little-endian) ----

    pub fn send_f32s(&self, dst: usize, tag: u32, data: &[f32]) -> Result<()> {
        self.send(dst, tag, f32s_to_bytes(data))
    }

    pub fn recv_f32s(&mut self, src: usize, tag: u32) -> Result<Vec<f32>> {
        bytes_to_f32s(&self.recv(src, tag)?)
    }

    pub fn send_u64s(&self, dst: usize, tag: u32, data: &[u64]) -> Result<()> {
        let mut out = Vec::with_capacity(data.len() * 8);
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.send(dst, tag, out)
    }

    pub fn recv_u64s(&mut self, src: usize, tag: u32) -> Result<Vec<u64>> {
        let b = self.recv(src, tag)?;
        if b.len() % 8 != 0 {
            return Err(Error::Cluster("u64 payload not 8-aligned".into()));
        }
        Ok(b.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

pub fn f32s_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(Error::Cluster("f32 payload not 4-aligned".into()));
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Universe;

    #[test]
    fn p2p_roundtrip() {
        let out = Universe::new(2, CostModel::free()).run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 7, &[1.0, 2.0, 3.0]).unwrap();
                0.0f32
            } else {
                comm.recv_f32s(0, 7).unwrap().iter().sum()
            }
        });
        assert_eq!(out[1], 6.0);
    }

    #[test]
    fn out_of_order_tags_are_buffered() {
        let out = Universe::new(2, CostModel::free()).run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 1, &[10.0]).unwrap();
                comm.send_f32s(1, 2, &[20.0]).unwrap();
                vec![]
            } else {
                // Ask for tag 2 first; tag 1 must be buffered, not lost.
                let b = comm.recv_f32s(0, 2).unwrap();
                let a = comm.recv_f32s(0, 1).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![10.0, 20.0]);
    }

    #[test]
    fn self_send_loopback() {
        let out = Universe::new(1, CostModel::free()).run(|mut comm| {
            comm.send_u64s(0, 3, &[42]).unwrap();
            comm.recv_u64s(0, 3).unwrap()[0]
        });
        assert_eq!(out[0], 42);
    }

    #[test]
    fn invalid_rank_rejected() {
        Universe::new(1, CostModel::free()).run(|comm| {
            assert!(comm.send(5, 0, vec![]).is_err());
        });
    }

    #[test]
    fn bytes_accounted_excluding_loopback() {
        let u = Universe::new(2, CostModel::gige10());
        let stats = u.stats();
        u.run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 0, &[0.0; 100]).unwrap(); // 400 B on the wire
                comm.send_f32s(0, 1, &[0.0; 50]).unwrap(); // loopback, free
                comm.recv_f32s(0, 1).unwrap();
            } else {
                comm.recv_f32s(0, 0).unwrap();
            }
        });
        assert_eq!(stats.bytes(), 400);
        assert_eq!(stats.messages(), 1);
        assert!(stats.sim_secs() >= 50e-6);
    }

    #[test]
    fn serialization_roundtrip() {
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&data)).unwrap(), data);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
