//! Communicators: rank handles over the shared world mesh.
//!
//! Each OS thread (rank) owns one mailbox — an mpsc receiver plus an
//! unexpected-message queue, like MPI's — and a view of the world-wide
//! sender mesh. A [`Comm`] is a *view* over that machinery: the world
//! communicator covers every rank, and [`Comm::split`] derives
//! MPI_Comm_split-style sub-communicators that re-use the parent's mesh
//! and mailbox instead of building a disjoint channel fabric. Messages
//! carry `(src, context, tag)`; the context id namespaces each
//! communicator's traffic so a rank can hold the world comm and any number
//! of derived comms on the same mailbox without cross-talk. `recv` matches
//! on `(src, context, tag)` and buffers out-of-order arrivals locally.
//!
//! Every communicator also carries its *level*'s interconnect pricing: a
//! [`CostModel`] and the [`NetStats`] it accounts into. A derived
//! communicator may inherit its parent's level ([`Comm::split`]) or be
//! pinned to a different one ([`Comm::split_with`] — e.g. a fast
//! intra-node link for solver sub-worlds under a slow inter-node worker
//! world), which is what makes per-level overhead accounting possible.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::costmodel::{CostModel, NetStats};
use super::fault::FaultPlan;
use crate::error::{Error, Result};

/// Message envelope on the simulated wire. `src` is a world-mesh index;
/// `ctx` is the sending communicator's context id.
#[derive(Debug)]
pub struct Envelope {
    pub src: usize,
    pub ctx: u32,
    pub tag: u32,
    pub payload: Vec<u8>,
}

/// One rank-thread's receive side: the mpsc inbox plus the
/// unexpected-message queue. Shared (via `Arc<Mutex<_>>`) between the
/// world communicator and every communicator split from it on this rank —
/// a rank is single-threaded SPMD, so the lock is never contended; it only
/// makes the sharing `Send`.
pub(super) struct Mailbox {
    rx: Receiver<Envelope>,
    pending: VecDeque<Envelope>,
}

impl Mailbox {
    pub(super) fn new(rx: Receiver<Envelope>) -> Mailbox {
        Mailbox { rx, pending: VecDeque::new() }
    }
}

/// Wire-free rendezvous for [`Comm::split`]: every rank of the parent
/// publishes its `(color, key)` and waits until the whole parent world has
/// done the same. This is control-plane setup (MPI pays it during
/// communicator construction, before any priced traffic), so it rides the
/// universe's shared memory and never touches the cost models.
#[derive(Default)]
pub(super) struct SplitBoard {
    slots: Mutex<HashMap<(u32, u32), SplitSlot>>,
    cv: Condvar,
}

#[derive(Default)]
struct SplitSlot {
    /// parent rank -> (color, key)
    entries: BTreeMap<usize, (u64, u64)>,
    reads: usize,
}

impl SplitBoard {
    /// Publish `(color, key)` under `(parent ctx, split seq)` and block
    /// until every rank in `expected` (ascending parent ranks; the whole
    /// parent world for an ordinary split, the survivor set for a
    /// post-failure one) has published; returns the full table ordered by
    /// parent rank. The slot is freed once every expected rank has read
    /// it. Times out (instead of deadlocking) if a peer never joins the
    /// collective, naming the ranks still missing from the slot.
    fn exchange(
        &self,
        slot: (u32, u32),
        expected: &[usize],
        rank: usize,
        color: u64,
        key: u64,
        timeout: Duration,
    ) -> Result<Vec<(usize, u64, u64)>> {
        let deadline = Instant::now() + timeout;
        let mut slots = self.slots.lock().expect("split board poisoned");
        slots.entry(slot).or_default().entries.insert(rank, (color, key));
        self.cv.notify_all();
        loop {
            {
                let s = slots.get_mut(&slot).expect("split slot vanished");
                if s.entries.len() == expected.len() {
                    let table: Vec<(usize, u64, u64)> =
                        s.entries.iter().map(|(&r, &(c, k))| (r, c, k)).collect();
                    s.reads += 1;
                    if s.reads == expected.len() {
                        slots.remove(&slot);
                    }
                    self.cv.notify_all();
                    return Ok(table);
                }
            }
            let remaining = deadline.saturating_duration_since(Instant::now());
            if remaining.is_zero() {
                // Name the absentees BEFORE withdrawing our own entry —
                // the diagnostic must describe the slot as we saw it.
                let missing: Vec<String> = {
                    let s = slots.get(&slot);
                    expected
                        .iter()
                        .filter(|r| !s.is_some_and(|s| s.entries.contains_key(r)))
                        .map(|r| r.to_string())
                        .collect()
                };
                // Withdraw our entry so a late-arriving peer cannot
                // "complete" the split with a member that already gave up —
                // it will time out (fail fast) against the missing entry
                // instead. The last withdrawer frees the slot. Like MPI, a
                // failed collective leaves the communicator unusable for
                // further splits (retries would desynchronize sequence
                // numbers across ranks).
                if let Some(s) = slots.get_mut(&slot) {
                    s.entries.remove(&rank);
                    if s.entries.is_empty() {
                        slots.remove(&slot);
                    }
                }
                return Err(Error::Cluster(format!(
                    "rank {rank}: timeout in Comm::split (rank(s) {} never joined the collective)",
                    missing.join(", ")
                )));
            }
            slots = self
                .cv
                .wait_timeout(slots, remaining)
                .expect("split board poisoned")
                .0;
        }
    }
}

/// Deterministic child context id: every member of a split group computes
/// the same value locally (split is collective, so all members share the
/// parent context and split sequence number), and sibling color groups get
/// distinct ids so their own nested collectives never share a board slot.
/// The color's two 32-bit halves are mixed in separate rounds (a plain
/// xor-fold would give colors like `0` and `0x1_0000_0001` the same id).
fn derive_ctx(parent: u32, seq: u32, color: u64) -> u32 {
    const P: u32 = 0x0100_0193; // FNV-1a prime
    let mut h = 0x811C_9DC5u32 ^ parent;
    h = h.wrapping_mul(P) ^ seq;
    h = h.wrapping_mul(P) ^ (color as u32);
    h = h.wrapping_mul(P) ^ ((color >> 32) as u32);
    // Never collide with the world context (0).
    h.wrapping_mul(P) | 1
}

/// Per-rank communicator handle (world or derived).
pub struct Comm {
    /// My rank *within this communicator*.
    rank: usize,
    /// This communicator's size.
    size: usize,
    /// Context id namespacing this communicator's traffic.
    ctx: u32,
    /// Communicator rank -> world-mesh index.
    group: Arc<Vec<usize>>,
    /// My world-mesh index (`group[rank]`, cached).
    world_rank: usize,
    senders: Arc<Vec<Sender<Envelope>>>,
    mailbox: Arc<Mutex<Mailbox>>,
    stats: Arc<NetStats>,
    model: CostModel,
    recv_timeout: Duration,
    /// Collective split counter (derives deterministic child contexts).
    splits: u32,
    board: Arc<SplitBoard>,
    /// Scripted faults for this world (empty outside fault tests).
    faults: Arc<FaultPlan>,
}

impl Comm {
    /// World communicator for one rank (built by `Universe::run`).
    #[allow(clippy::too_many_arguments)]
    pub(super) fn root(
        rank: usize,
        size: usize,
        senders: Arc<Vec<Sender<Envelope>>>,
        inbox: Receiver<Envelope>,
        stats: Arc<NetStats>,
        model: CostModel,
        board: Arc<SplitBoard>,
        recv_timeout: Duration,
        faults: Arc<FaultPlan>,
    ) -> Comm {
        Comm {
            rank,
            size,
            ctx: 0,
            group: Arc::new((0..size).collect()),
            world_rank: rank,
            senders,
            mailbox: Arc::new(Mutex::new(Mailbox::new(inbox))),
            stats,
            model,
            recv_timeout,
            splits: 0,
            board,
            faults,
        }
    }

    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    pub fn model(&self) -> CostModel {
        self.model
    }

    /// Override the receive timeout (the world default comes from
    /// `Universe::with_recv_timeout`, itself 30s unless configured, e.g.
    /// via `--comm-timeout`). Derived communicators inherit the parent's
    /// value at split time. Failure-injection tests use short timeouts to
    /// exercise the deadlock-detection path.
    pub fn set_recv_timeout(&mut self, timeout: Duration) {
        self.recv_timeout = timeout;
    }

    /// The timeout after which a silent peer is suspected dead.
    pub fn recv_timeout(&self) -> Duration {
        self.recv_timeout
    }

    /// My rank in the *world* mesh (stable across splits; the rank space
    /// [`FaultPlan`] addresses).
    pub fn world_rank(&self) -> usize {
        self.world_rank
    }

    /// Communicator rank -> world rank for every member of this comm.
    pub fn group(&self) -> &[usize] {
        &self.group
    }

    /// Apply any scripted fault for this rank at solver iteration `iter`:
    /// scripted delays sleep inline; returns `true` when the plan kills
    /// this rank here, in which case the caller must abandon the solve and
    /// let the rank thread die (dropping its inbox, so peers observe the
    /// real failure signatures: fast-failing sends and timed-out recvs).
    pub fn fault_tick(&self, iter: usize) -> bool {
        if self.faults.is_empty() {
            return false;
        }
        if let Some(d) = self.faults.delay_at(self.world_rank, iter) {
            std::thread::sleep(d);
        }
        self.faults.kills_at(self.world_rank, iter)
    }

    /// MPI_Comm_split: collectively derive a sub-communicator from this
    /// one. Every rank of the parent must call this the same number of
    /// times in the same order (standard MPI collective semantics). Ranks
    /// passing the same `color` form one group; within a group, ranks are
    /// ordered by `(key, parent rank)` — so `key = parent rank` (or any
    /// constant) preserves the parent's rank order, which in turn
    /// preserves the rank-order tie-breaking of the pair reductions.
    ///
    /// The child re-uses the parent's mesh and mailbox (no new channels)
    /// under a fresh context id, and inherits the parent's cost model and
    /// stats — same interconnect level. Use [`Comm::split_with`] to pin
    /// the child to a different level.
    pub fn split(&mut self, color: usize, key: usize) -> Result<Comm> {
        let (model, stats) = (self.model, Arc::clone(&self.stats));
        self.split_with(color, key, model, stats)
    }

    /// [`Comm::split`] with an explicit interconnect level for the child:
    /// its traffic is priced by `model` and accounted into `stats` (e.g. a
    /// solver sub-world on the fast intra-node link while the parent
    /// worker world stays on the inter-node link).
    pub fn split_with(
        &mut self,
        color: usize,
        key: usize,
        model: CostModel,
        stats: Arc<NetStats>,
    ) -> Result<Comm> {
        self.splits += 1;
        let expected: Vec<usize> = (0..self.size).collect();
        let table = self.board.exchange(
            (self.ctx, self.splits),
            &expected,
            self.rank,
            color as u64,
            key as u64,
            self.recv_timeout,
        )?;
        let mut members: Vec<(u64, usize)> = table
            .iter()
            .filter(|&&(_, c, _)| c == color as u64)
            .map(|&(r, _, k)| (k, r))
            .collect();
        members.sort_unstable(); // by (key, parent rank)
        let sub_rank = members
            .iter()
            .position(|&(_, r)| r == self.rank)
            .expect("own rank missing from its split group");
        let group: Vec<usize> = members.iter().map(|&(_, r)| self.group[r]).collect();
        Ok(Comm {
            rank: sub_rank,
            size: members.len(),
            ctx: derive_ctx(self.ctx, self.splits, color as u64),
            group: Arc::new(group),
            world_rank: self.world_rank,
            senders: Arc::clone(&self.senders),
            mailbox: Arc::clone(&self.mailbox),
            stats,
            model,
            recv_timeout: self.recv_timeout,
            splits: 0,
            board: Arc::clone(&self.board),
            faults: Arc::clone(&self.faults),
        })
    }

    /// Derive a sub-communicator over the `survivors` of this one —
    /// [`Comm::split`] for a world that has lost ranks. An ordinary split
    /// is collective over ALL parent ranks, so a dead peer would stall it
    /// until timeout; here the rendezvous waits only for the listed
    /// survivors (ascending parent ranks, which must include the caller).
    /// Every survivor must pass the same list — they agreed on it in the
    /// failure-consensus round — and ranks keep their relative order, so
    /// the pair reductions' rank-order tie-breaking is preserved.
    ///
    /// The child inherits this communicator's level (model + stats),
    /// timeout, and fault plan, under a fresh context id — stale traffic
    /// from the failed epoch can never match the new communicator.
    pub fn split_survivors(&mut self, survivors: &[usize]) -> Result<Comm> {
        assert!(
            survivors.windows(2).all(|w| w[0] < w[1]),
            "survivor list must be ascending and duplicate-free"
        );
        let me = survivors
            .iter()
            .position(|&r| r == self.rank)
            .expect("caller must be in its own survivor list");
        self.splits += 1;
        self.board.exchange(
            (self.ctx, self.splits),
            survivors,
            self.rank,
            0,
            self.rank as u64,
            self.recv_timeout,
        )?;
        let group: Vec<usize> = survivors.iter().map(|&r| self.group[r]).collect();
        Ok(Comm {
            rank: me,
            size: survivors.len(),
            ctx: derive_ctx(self.ctx, self.splits, 0),
            group: Arc::new(group),
            world_rank: self.world_rank,
            senders: Arc::clone(&self.senders),
            mailbox: Arc::clone(&self.mailbox),
            stats: Arc::clone(&self.stats),
            model: self.model,
            recv_timeout: self.recv_timeout,
            splits: 0,
            board: Arc::clone(&self.board),
            faults: Arc::clone(&self.faults),
        })
    }

    /// Send raw bytes to `dst` (a rank of *this* communicator) with a tag.
    /// Self-sends are allowed (loopback) and accounted at zero cost.
    pub fn send(&self, dst: usize, tag: u32, payload: Vec<u8>) -> Result<()> {
        if dst >= self.size {
            return Err(Error::Cluster(format!("send to invalid rank {dst}")));
        }
        let world_dst = self.group[dst];
        if world_dst != self.world_rank {
            self.stats.record(payload.len(), &self.model);
        }
        self.senders[world_dst]
            .send(Envelope { src: self.world_rank, ctx: self.ctx, tag, payload })
            .map_err(|_| Error::Cluster(format!("rank {dst} hung up")))
    }

    /// Receive the next message matching (src, tag) on this communicator,
    /// buffering others (including other communicators' traffic).
    pub fn recv(&mut self, src: usize, tag: u32) -> Result<Vec<u8>> {
        if src >= self.size {
            return Err(Error::Cluster(format!("recv from invalid rank {src}")));
        }
        let world_src = self.group[src];
        let mut mb = self.mailbox.lock().expect("mailbox poisoned");
        // Check the unexpected-message queue first.
        if let Some(pos) = mb
            .pending
            .iter()
            .position(|e| e.src == world_src && e.ctx == self.ctx && e.tag == tag)
        {
            return Ok(mb.pending.remove(pos).unwrap().payload);
        }
        let deadline = Instant::now() + self.recv_timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            let env = mb.rx.recv_timeout(remaining).map_err(|_| {
                Error::Cluster(format!(
                    "rank {}: timeout waiting for (src={src}, tag={tag})",
                    self.rank
                ))
            })?;
            if env.src == world_src && env.ctx == self.ctx && env.tag == tag {
                return Ok(env.payload);
            }
            mb.pending.push_back(env);
        }
    }

    // ---- typed helpers (f32/u64 slices in little-endian) ----

    pub fn send_f32s(&self, dst: usize, tag: u32, data: &[f32]) -> Result<()> {
        self.send(dst, tag, f32s_to_bytes(data))
    }

    pub fn recv_f32s(&mut self, src: usize, tag: u32) -> Result<Vec<f32>> {
        bytes_to_f32s(&self.recv(src, tag)?)
    }

    pub fn send_u64s(&self, dst: usize, tag: u32, data: &[u64]) -> Result<()> {
        let mut out = Vec::with_capacity(data.len() * 8);
        for v in data {
            out.extend_from_slice(&v.to_le_bytes());
        }
        self.send(dst, tag, out)
    }

    pub fn recv_u64s(&mut self, src: usize, tag: u32) -> Result<Vec<u64>> {
        let b = self.recv(src, tag)?;
        if b.len() % 8 != 0 {
            return Err(Error::Cluster("u64 payload not 8-aligned".into()));
        }
        Ok(b.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

pub fn f32s_to_bytes(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 4);
    for v in data {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

pub fn bytes_to_f32s(b: &[u8]) -> Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(Error::Cluster("f32 payload not 4-aligned".into()));
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Universe;

    #[test]
    fn p2p_roundtrip() {
        let out = Universe::new(2, CostModel::free()).run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 7, &[1.0, 2.0, 3.0]).unwrap();
                0.0f32
            } else {
                comm.recv_f32s(0, 7).unwrap().iter().sum()
            }
        });
        assert_eq!(out[1], 6.0);
    }

    #[test]
    fn out_of_order_tags_are_buffered() {
        let out = Universe::new(2, CostModel::free()).run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 1, &[10.0]).unwrap();
                comm.send_f32s(1, 2, &[20.0]).unwrap();
                vec![]
            } else {
                // Ask for tag 2 first; tag 1 must be buffered, not lost.
                let b = comm.recv_f32s(0, 2).unwrap();
                let a = comm.recv_f32s(0, 1).unwrap();
                vec![a[0], b[0]]
            }
        });
        assert_eq!(out[1], vec![10.0, 20.0]);
    }

    #[test]
    fn self_send_loopback() {
        let out = Universe::new(1, CostModel::free()).run(|mut comm| {
            comm.send_u64s(0, 3, &[42]).unwrap();
            comm.recv_u64s(0, 3).unwrap()[0]
        });
        assert_eq!(out[0], 42);
    }

    #[test]
    fn invalid_rank_rejected() {
        Universe::new(1, CostModel::free()).run(|comm| {
            assert!(comm.send(5, 0, vec![]).is_err());
        });
    }

    #[test]
    fn bytes_accounted_excluding_loopback() {
        let u = Universe::new(2, CostModel::gige10());
        let stats = u.stats();
        u.run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 0, &[0.0; 100]).unwrap(); // 400 B on the wire
                comm.send_f32s(0, 1, &[0.0; 50]).unwrap(); // loopback, free
                comm.recv_f32s(0, 1).unwrap();
            } else {
                comm.recv_f32s(0, 0).unwrap();
            }
        });
        assert_eq!(stats.bytes(), 400);
        assert_eq!(stats.messages(), 1);
        assert!(stats.sim_secs() >= 50e-6);
    }

    #[test]
    fn serialization_roundtrip() {
        let data = vec![1.5f32, -2.25, 0.0, f32::MAX];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&data)).unwrap(), data);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }

    // ---- split ----

    #[test]
    fn split_halves_route_within_their_group() {
        // 4 ranks -> two disjoint pairs; each pair exchanges privately
        // using *sub*-ranks (0 and 1 in every group).
        let out = Universe::new(4, CostModel::free()).run(|mut comm| {
            let color = comm.rank() / 2;
            let mut sub = comm.split(color, comm.rank()).unwrap();
            assert_eq!(sub.size(), 2);
            if sub.rank() == 0 {
                sub.send_f32s(1, 5, &[comm.rank() as f32]).unwrap();
                -1.0
            } else {
                sub.recv_f32s(0, 5).unwrap()[0]
            }
        });
        // Rank 1 hears from rank 0; rank 3 hears from rank 2.
        assert_eq!(out[1], 0.0);
        assert_eq!(out[3], 2.0);
    }

    #[test]
    fn split_key_reorders_sub_ranks() {
        // Reversed keys invert the rank order inside the group.
        let out = Universe::new(3, CostModel::free()).run(|mut comm| {
            let sub = comm.split(0, comm.size() - comm.rank()).unwrap();
            (comm.rank(), sub.rank())
        });
        assert_eq!(out, vec![(0, 2), (1, 1), (2, 0)]);
    }

    #[test]
    fn split_ties_on_key_preserve_parent_order() {
        let out = Universe::new(4, CostModel::free()).run(|mut comm| {
            let sub = comm.split(comm.rank() % 2, 0).unwrap();
            (comm.rank(), sub.rank(), sub.size())
        });
        // Even parents 0,2 -> sub-ranks 0,1; odd parents 1,3 -> 0,1.
        assert_eq!(out, vec![(0, 0, 2), (1, 0, 2), (2, 1, 2), (3, 1, 2)]);
    }

    #[test]
    fn parent_and_child_traffic_do_not_cross() {
        // Same (src, tag) on parent and child contexts: each recv must get
        // its own communicator's message even when the other arrives first.
        let out = Universe::new(2, CostModel::free()).run(|mut comm| {
            let mut sub = comm.split(0, comm.rank()).unwrap();
            if comm.rank() == 0 {
                sub.send_f32s(1, 7, &[111.0]).unwrap();
                comm.send_f32s(1, 7, &[222.0]).unwrap();
                vec![]
            } else {
                // Parent first: the child message (already queued) must be
                // buffered past it, then found by the child recv.
                let parent = comm.recv_f32s(0, 7).unwrap();
                let child = sub.recv_f32s(0, 7).unwrap();
                vec![parent[0], child[0]]
            }
        });
        assert_eq!(out[1], vec![222.0, 111.0]);
    }

    #[test]
    fn split_with_accounts_to_its_own_level() {
        let u = Universe::new(2, CostModel::gige10());
        let world_stats = u.stats();
        let intra_stats = NetStats::new();
        let intra_probe = Arc::clone(&intra_stats);
        u.run(move |mut comm| {
            let mut sub = comm
                .split_with(0, comm.rank(), CostModel::free(), Arc::clone(&intra_probe))
                .unwrap();
            if sub.rank() == 0 {
                sub.send_f32s(1, 1, &[0.0; 10]).unwrap();
            } else {
                sub.recv_f32s(0, 1).unwrap();
            }
        });
        assert_eq!(world_stats.bytes(), 0, "world level must not see sub traffic");
        assert_eq!(intra_stats.bytes(), 40);
        assert_eq!(intra_stats.messages(), 1);
    }

    #[test]
    fn nested_split_of_a_split_works() {
        let out = Universe::new(4, CostModel::free()).run(|mut comm| {
            let mut half = comm.split(comm.rank() / 2, comm.rank()).unwrap();
            let solo = half.split(half.rank(), 0).unwrap();
            (half.size(), solo.size(), solo.rank())
        });
        for v in out {
            assert_eq!(v, (2, 1, 0));
        }
    }

    #[test]
    fn derived_contexts_are_distinct() {
        assert_ne!(derive_ctx(0, 1, 0), derive_ctx(0, 1, 1));
        assert_ne!(derive_ctx(0, 1, 0), derive_ctx(0, 2, 0));
        assert_ne!(derive_ctx(0, 1, 0), 0, "never the world context");
        let child = derive_ctx(0, 1, 3);
        assert_ne!(derive_ctx(child, 1, 0), derive_ctx(0, 1, 0));
        // The color halves are mixed in separate rounds: a symmetric
        // xor-fold would collide these two.
        assert_ne!(derive_ctx(0, 1, 0), derive_ctx(0, 1, 0x1_0000_0001));
        assert_ne!(derive_ctx(0, 1, 1), derive_ctx(0, 1, 1 << 32));
    }

    #[test]
    fn timed_out_split_withdraws_its_entry() {
        // Rank 0 gives up on a split; rank 1 arrives later and must NOT
        // see a completed collective containing the dead member — it
        // times out too (fail fast) instead of stalling in a sub-world
        // with a ghost rank.
        let out = Universe::new(2, CostModel::free()).run(|mut comm| {
            if comm.rank() == 0 {
                comm.set_recv_timeout(std::time::Duration::from_millis(50));
                comm.split(0, 0).is_err()
            } else {
                std::thread::sleep(std::time::Duration::from_millis(150));
                comm.set_recv_timeout(std::time::Duration::from_millis(50));
                comm.split(0, 0).is_err()
            }
        });
        assert!(out[0] && out[1], "both ranks must observe the failed split");
    }

    #[test]
    fn split_timeout_names_the_missing_ranks() {
        // Rank 2 never joins; the survivors' diagnostics must say WHICH
        // rank is absent, not just that "a peer" is.
        let out = Universe::new(3, CostModel::free()).run(|mut comm| {
            if comm.rank() == 2 {
                return String::new();
            }
            comm.set_recv_timeout(Duration::from_millis(50));
            comm.split(0, 0).unwrap_err().to_string()
        });
        for msg in &out[..2] {
            assert!(msg.contains("split"), "{msg}");
            // The first withdrawer may appear in the other's list too, but
            // the truly absent rank must always be named.
            assert!(msg.contains('2'), "{msg}");
            assert!(msg.contains("never joined"), "{msg}");
        }
    }

    #[test]
    fn split_survivors_regroups_without_the_dead_rank() {
        // Rank 1 "dies" (returns early, dropping its inbox); survivors
        // 0, 2, 3 regroup by rendezvousing among themselves only — an
        // ordinary split would stall against the dead member.
        let out = Universe::new(4, CostModel::free()).run(|mut comm| {
            if comm.rank() == 1 {
                return -1.0f32;
            }
            let mut sub = comm.split_survivors(&[0, 2, 3]).unwrap();
            assert_eq!(sub.size(), 3);
            assert_eq!(sub.group(), &[0, 2, 3], "world ranks preserved in order");
            if sub.rank() == 0 {
                sub.send_f32s(2, 9, &[7.5]).unwrap();
                0.0
            } else if sub.rank() == 2 {
                sub.recv_f32s(0, 9).unwrap()[0]
            } else {
                0.0
            }
        });
        assert_eq!(out[3], 7.5, "parent rank 3 is survivor rank 2");
    }

    #[test]
    fn stale_parent_traffic_does_not_cross_into_the_survivor_comm() {
        // A message sent on the parent context before the failure must not
        // satisfy a receive on the freshly derived survivor context.
        let out = Universe::new(3, CostModel::free()).run(|mut comm| {
            if comm.rank() == 2 {
                // The "failing" rank gets one last parent-ctx message out
                // before dying.
                comm.send_f32s(0, 4, &[666.0]).unwrap();
                return 0.0f32;
            }
            let mut sub = comm.split_survivors(&[0, 1]).unwrap();
            if sub.rank() == 1 {
                sub.send_f32s(0, 4, &[1.25]).unwrap();
                0.0
            } else {
                sub.recv_f32s(1, 4).unwrap()[0]
            }
        });
        assert_eq!(out[0], 1.25, "survivor recv must skip the stale epoch's payload");
    }
}
