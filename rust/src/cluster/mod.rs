//! Simulated MPI: the distributed-memory half of the paper's hybrid
//! architecture (paper Fig 1), reproduced in-process.
//!
//! Real MPICH ranks become OS threads; the interconnect becomes tagged
//! channels with a configurable latency/bandwidth *cost model* that
//! accounts — without sleeping — the simulated wire time and exact bytes of
//! every transfer. That makes the paper's "MPI communication overhead is
//! only initial scatter + final gather" claim *measurable* (Table IV
//! discussion, EXPERIMENTS.md).
//!
//! The API mirrors the MPI subset the paper's Fig 4 pseudocode needs:
//! point-to-point `send`/`recv`, and the collectives `bcast`, `scatter`,
//! `gather`, `allgather`, `allreduce` (sum and MINLOC/MAXLOC candidate
//! reductions — the working-set selection primitive of the distributed
//! solver), `barrier` — all implemented over p2p exactly as a simple MPI
//! layer would.

pub mod collectives;
pub mod comm;
pub mod costmodel;
pub mod universe;

pub use collectives::PairCandidate;
pub use comm::Comm;
pub use costmodel::{CostModel, NetStats};
pub use universe::Universe;
