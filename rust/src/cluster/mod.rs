//! Simulated MPI: the distributed-memory half of the paper's hybrid
//! architecture (paper Fig 1), reproduced in-process.
//!
//! Real MPICH ranks become OS threads; the interconnect becomes tagged
//! channels with a configurable latency/bandwidth *cost model* that
//! accounts — without sleeping — the simulated wire time and exact bytes of
//! every transfer. That makes the paper's "MPI communication overhead is
//! only initial scatter + final gather" claim *measurable* (Table IV
//! discussion, EXPERIMENTS.md).
//!
//! The API mirrors the MPI subset the paper's Fig 4 pseudocode needs:
//! point-to-point `send`/`recv`, communicator derivation
//! (`MPI_Comm_split` → [`Comm::split`]), and the collectives `bcast`,
//! `scatter`, `gather`, `allgather`, `allreduce` (sum and MINLOC/MAXLOC
//! candidate reductions — the working-set selection primitive of the
//! distributed solver), `barrier` — all implemented over p2p exactly as a
//! simple MPI layer would, and all operating on any communicator, world
//! or derived.
//!
//! # Flat → hierarchical: the communicator migration
//!
//! Through PR 2 the cluster was a flat [`Universe`]: one rank mesh, one
//! [`CostModel`], one world-wide [`NetStats`]. Nesting (a distributed QP
//! inside a worker rank) was simulated by *spawning* a second, unrelated
//! universe — which silently shared the host and priced node-local solver
//! chatter like cluster ethernet, making a Table-IV-style overhead split
//! impossible. The hierarchy is now first-class:
//!
//! * [`Topology`] declares the machine's levels (outermost first, e.g.
//!   `inter` workers × `intra` solver ranks), each level carrying its own
//!   cost model and its own traffic ledger;
//! * [`Topology::universe`] spawns *one* world of `total_ranks()` threads
//!   wired to the outermost level;
//! * inside the SPMD body, [`Comm::split`] / [`Comm::split_with`] derive
//!   sub-communicators MPI_Comm_split-style — same mesh and mailbox, a
//!   fresh context id, ranks regrouped by `(color, key)` — instead of
//!   building disjoint channel fabrics. `split_with` pins the child to a
//!   different level (model + ledger), which is how intra-node traffic is
//!   priced and measured apart from inter-node traffic;
//! * [`Topology::net`] snapshots the ledgers as a [`NetReport`] whose
//!   roll-up equals what the old flat accounting would have recorded —
//!   the invariant the property tests pin down.
//!
//! **Split vs spawn:** derive with `split` whenever the sub-world's ranks
//! already exist in the parent world (the coordinator's solver sub-worlds
//! — communication patterns, ordering guarantees and accounting all stay
//! inside one machine model). Spawn a fresh `Universe` only for a
//! genuinely separate machine: a standalone engine run, a test fixture,
//! or a world whose lifetime outlives any parent SPMD body.
//! Rank-order guarantees survive both: a split group is ordered by
//! `(key, parent rank)`, so `key = parent rank` (or a constant) keeps the
//! contiguous ascending order that makes the MINLOC/MAXLOC reductions'
//! tie-breaking bit-identical to a serial ascending scan.
//!
//! # Surviving rank loss: detect, agree, re-shard, resume
//!
//! Through PR 8 a dead rank meant a clean abort: sends to its dropped
//! inbox failed fast ("rank r hung up"), receives from it timed out, and
//! the failure-injection tests pinned down that we *error out* rather
//! than deadlock. The elastic layer turns that abort into a recovery:
//!
//! 1. **Detect** — any collective erroring with a dead-peer signature
//!    ([`fault::is_comm_failure`]) makes the survivor enter
//!    [`Comm::failure_consensus`]: an alive-probe round plus a
//!    suspicion-mask union on the failed communicator, after which every
//!    survivor holds the *same* dead-rank list. The receive timeout
//!    (default 30s, `--comm-timeout`, [`Universe::with_recv_timeout`])
//!    doubles as the failure-detection horizon.
//! 2. **Agree & regroup** — survivors derive a fresh sub-world with
//!    [`Comm::split_survivors`]: the split-board rendezvous waits only
//!    for the agreed survivor set (an ordinary [`Comm::split`] would
//!    stall against the dead member), keeps relative rank order (so the
//!    pair reductions' tie-breaking is unchanged), and mints a fresh
//!    context id so stale traffic from the failed epoch can never match.
//! 3. **Re-shard & resume** — the solver re-partitions rows over the
//!    survivors and restores the last consistent checkpoint (exact f64
//!    alpha + full gradient + active set; format documented in
//!    `data::checkpoint`, written atomically via write-then-rename and
//!    validated — magic/version/length/checksum/problem-fingerprint —
//!    before a single word is trusted). Because the distributed
//!    trajectory is partition-independent, the resumed solve replays the
//!    fault-free trajectory bit-for-bit.
//!
//! Faults themselves are first-class test inputs: a [`FaultPlan`]
//! scripts kills/delays by (world rank, iteration) through the
//! [`Universe`], and a [`FaultReport`] counts detections, resharding
//! rounds, checkpoint restores, and wasted iterations next to the
//! per-level [`NetReport`]s.

pub mod collectives;
pub mod comm;
pub mod costmodel;
pub mod fault;
pub mod topology;
pub mod universe;

pub use collectives::PairCandidate;
pub use comm::Comm;
pub use costmodel::{CostModel, NetStats};
pub use fault::{is_comm_failure, FaultPlan, FaultReport};
pub use topology::{Level, LevelNet, NetReport, Topology, LEVEL_INTER, LEVEL_INTRA};
pub use universe::Universe;
