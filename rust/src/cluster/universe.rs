//! Universe: spawn P ranks as threads and run an SPMD closure on each
//! (the `mpiexec -n P` of the simulated cluster).
//!
//! The universe owns the world-level interconnect accounting; sub-worlds
//! are *not* new universes but communicators derived inside the SPMD body
//! via [`Comm::split`] / [`Comm::split_with`] (see [`super::topology`] for
//! the level bookkeeping). `Universe::new` keeps the historical flat
//! behaviour — a fresh world-level [`NetStats`]; `Universe::with_stats`
//! wires the world to an externally owned level (what
//! [`super::Topology::universe`] does).

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use super::comm::{Comm, Envelope, SplitBoard};
use super::costmodel::{CostModel, NetStats};
use super::fault::FaultPlan;

/// The receive timeout every rank starts with unless the universe (or
/// `--comm-timeout`) says otherwise.
pub const DEFAULT_RECV_TIMEOUT: Duration = Duration::from_secs(30);

/// A P-rank SPMD world.
pub struct Universe {
    size: usize,
    model: CostModel,
    stats: Arc<NetStats>,
    recv_timeout: Duration,
    faults: Arc<FaultPlan>,
}

impl Universe {
    pub fn new(size: usize, model: CostModel) -> Universe {
        Universe::with_stats(size, model, NetStats::new())
    }

    /// A world whose traffic accounts into an externally owned level
    /// (e.g. the first level of a [`super::Topology`]).
    pub fn with_stats(size: usize, model: CostModel, stats: Arc<NetStats>) -> Universe {
        assert!(size > 0, "universe needs at least one rank");
        Universe {
            size,
            model,
            stats,
            recv_timeout: DEFAULT_RECV_TIMEOUT,
            faults: Arc::new(FaultPlan::default()),
        }
    }

    /// Set the default receive timeout every rank's world communicator
    /// starts with (derived communicators inherit it at split time). This
    /// is the `--comm-timeout` knob; it doubles as the failure-detection
    /// horizon — a peer silent for this long is suspected dead.
    pub fn with_recv_timeout(mut self, timeout: Duration) -> Universe {
        self.recv_timeout = timeout;
        self
    }

    /// Script deterministic faults (kill/delay by world rank + iteration)
    /// into this world; every rank's [`Comm::fault_tick`] consults the
    /// same plan.
    pub fn with_faults(mut self, faults: FaultPlan) -> Universe {
        self.faults = Arc::new(faults);
        self
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Shared byte/time accounting for the world level. Traffic on
    /// communicators split onto other levels lands in *their* stats.
    pub fn stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }

    /// Run `f(comm)` on every rank; returns per-rank results ordered by
    /// rank. Panics in a rank propagate (fail-fast, like an MPI abort).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(Comm) -> T + Send + Sync + 'static,
    {
        // One shared sender mesh + per-rank inboxes; derived communicators
        // re-use this fabric under fresh context ids instead of building
        // their own.
        let mut senders = Vec::with_capacity(self.size);
        let mut inboxes = Vec::with_capacity(self.size);
        for _ in 0..self.size {
            let (tx, rx) = mpsc::channel::<Envelope>();
            senders.push(tx);
            inboxes.push(rx);
        }
        let senders = Arc::new(senders);
        let board = Arc::new(SplitBoard::default());

        let f = Arc::new(f);
        let mut handles = Vec::with_capacity(self.size);
        for (rank, inbox) in inboxes.into_iter().enumerate() {
            let comm = Comm::root(
                rank,
                self.size,
                Arc::clone(&senders),
                inbox,
                Arc::clone(&self.stats),
                self.model,
                Arc::clone(&board),
                self.recv_timeout,
                Arc::clone(&self.faults),
            );
            let f = Arc::clone(&f);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rank-{rank}"))
                    .spawn(move || f(comm))
                    .expect("spawn rank thread"),
            );
        }
        // Drop the setup copies so only live ranks keep the fabric alive.
        drop(senders);
        drop(board);

        handles
            .into_iter()
            .enumerate()
            .map(|(rank, h)| match h.join() {
                Ok(v) => v,
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .map(|s| s.as_str())
                        .or_else(|| e.downcast_ref::<&str>().copied())
                        .unwrap_or("<non-string panic>");
                    panic!("rank {rank}: {msg}");
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_ordered_by_rank() {
        let out = Universe::new(4, CostModel::free()).run(|comm| comm.rank() * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn size_visible_to_all_ranks() {
        let out = Universe::new(3, CostModel::free()).run(|comm| comm.size());
        assert_eq!(out, vec![3, 3, 3]);
    }

    #[test]
    #[should_panic]
    fn zero_ranks_rejected() {
        Universe::new(0, CostModel::free());
    }

    #[test]
    #[should_panic(expected = "rank 2")]
    fn rank_panic_propagates() {
        Universe::new(3, CostModel::free()).run(|comm| {
            if comm.rank() == 2 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn external_stats_see_world_traffic() {
        let level = NetStats::new();
        let u = Universe::with_stats(2, CostModel::gige10(), Arc::clone(&level));
        u.run(|mut comm| {
            if comm.rank() == 0 {
                comm.send_f32s(1, 0, &[0.0; 8]).unwrap();
            } else {
                comm.recv_f32s(0, 0).unwrap();
            }
        });
        assert_eq!(level.bytes(), 32);
        assert_eq!(level.messages(), 1);
    }

    #[test]
    fn configured_recv_timeout_reaches_every_rank() {
        let out = Universe::new(2, CostModel::free())
            .with_recv_timeout(Duration::from_millis(40))
            .run(|mut comm| {
                assert_eq!(comm.recv_timeout(), Duration::from_millis(40));
                if comm.rank() == 0 {
                    // And it actually governs recv on a silent peer.
                    comm.recv_f32s(1, 0).unwrap_err().to_string()
                } else {
                    String::new()
                }
            });
        assert!(out[0].contains("timeout"), "{}", out[0]);
    }

    #[test]
    fn fault_plan_kills_and_delays_deterministically() {
        let plan = FaultPlan::new().kill(1, 3).delay(0, 0, Duration::from_millis(1));
        let out = Universe::new(2, CostModel::free()).with_faults(plan).run(|comm| {
            for iter in 0..10 {
                if comm.fault_tick(iter) {
                    return iter as i64;
                }
            }
            -1
        });
        assert_eq!(out, vec![-1, 3], "rank 1 dies exactly at iteration 3, rank 0 never");
    }
}
