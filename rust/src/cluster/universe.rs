//! Universe: spawn P ranks as threads and run an SPMD closure on each
//! (the `mpiexec -n P` of the simulated cluster).

use std::sync::mpsc;
use std::sync::Arc;

use super::comm::{Comm, Envelope};
use super::costmodel::{CostModel, NetStats};

/// A P-rank SPMD world.
pub struct Universe {
    size: usize,
    model: CostModel,
    stats: Arc<NetStats>,
}

impl Universe {
    pub fn new(size: usize, model: CostModel) -> Universe {
        assert!(size > 0, "universe needs at least one rank");
        Universe { size, model, stats: NetStats::new() }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Shared byte/time accounting for the whole world.
    pub fn stats(&self) -> Arc<NetStats> {
        Arc::clone(&self.stats)
    }

    /// Run `f(comm)` on every rank; returns per-rank results ordered by
    /// rank. Panics in a rank propagate (fail-fast, like an MPI abort).
    pub fn run<T, F>(&self, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(Comm) -> T + Send + Sync + 'static,
    {
        // Build the all-to-all channel mesh.
        let mut senders = Vec::with_capacity(self.size);
        let mut inboxes = Vec::with_capacity(self.size);
        for _ in 0..self.size {
            let (tx, rx) = mpsc::channel::<Envelope>();
            senders.push(tx);
            inboxes.push(rx);
        }

        let f = Arc::new(f);
        let mut handles = Vec::with_capacity(self.size);
        for (rank, inbox) in inboxes.into_iter().enumerate() {
            let comm = Comm::new(
                rank,
                self.size,
                senders.clone(),
                inbox,
                Arc::clone(&self.stats),
                self.model,
            );
            let f = Arc::clone(&f);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("rank-{rank}"))
                    .spawn(move || f(comm))
                    .expect("spawn rank thread"),
            );
        }
        // Drop our copies of the senders so rank hangups are detectable.
        drop(senders);

        handles
            .into_iter()
            .enumerate()
            .map(|(rank, h)| match h.join() {
                Ok(v) => v,
                Err(e) => {
                    let msg = e
                        .downcast_ref::<String>()
                        .map(|s| s.as_str())
                        .or_else(|| e.downcast_ref::<&str>().copied())
                        .unwrap_or("<non-string panic>");
                    panic!("rank {rank}: {msg}");
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_ordered_by_rank() {
        let out = Universe::new(4, CostModel::free()).run(|comm| comm.rank() * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn size_visible_to_all_ranks() {
        let out = Universe::new(3, CostModel::free()).run(|comm| comm.size());
        assert_eq!(out, vec![3, 3, 3]);
    }

    #[test]
    #[should_panic]
    fn zero_ranks_rejected() {
        Universe::new(0, CostModel::free());
    }

    #[test]
    #[should_panic(expected = "rank 2")]
    fn rank_panic_propagates() {
        Universe::new(3, CostModel::free()).run(|comm| {
            if comm.rank() == 2 {
                panic!("boom");
            }
        });
    }
}
