//! Deterministic fault injection and recovery accounting.
//!
//! Real clusters lose ranks at the worst possible iteration; a simulated
//! cluster can lose them at a *chosen* one. A [`FaultPlan`] scripts
//! failures against world ranks — kill rank `r` when its solver reaches
//! iteration `k`, or delay it there by `d` — and travels through the
//! [`super::Universe`] into every rank's [`super::Comm`], so the solver
//! loop can consult it with one cheap call per iteration
//! ([`super::Comm::fault_tick`]). A killed rank's thread simply returns:
//! its inbox receiver drops, peers' sends to it fail fast with
//! "rank r hung up", and their receives time out — exactly the two
//! signatures the recovery path classifies as a suspected failure.
//!
//! Because the plan is data (not a random process), a kill at iteration
//! `k` reproduces the same detection, the same survivor consensus, and —
//! with checkpoint restore — the same bit-for-bit resumed trajectory on
//! every run, which is what makes recovery *testable* rather than merely
//! plausible.
//!
//! [`FaultReport`] is the ledger on the other side: how many failures
//! were detected, how many times the rows were re-sharded over survivors,
//! how many checkpoint restores happened, and how many solver iterations
//! were thrown away (work past the last consistent checkpoint). It rides
//! in `SolveOutcome` next to the per-level `NetReport`s and rolls up
//! through `MulticlassReport`.

use std::time::Duration;

use crate::error::Error;

/// Does this error carry a dead-peer signature — a send into a dropped
/// inbox ("hung up") or an expired receive ("timeout")? Those are the
/// only two ways a fail-stop rank manifests to its peers, and the only
/// errors the recovery path treats as survivable; anything else (length
/// mismatches, invalid ranks, decode failures) is a logic error and
/// still fails fast.
pub fn is_comm_failure(e: &Error) -> bool {
    match e {
        Error::Cluster(m) => m.contains("hung up") || m.contains("timeout"),
        _ => false,
    }
}

/// One scripted fault against a single world rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Fault {
    /// Rank `rank` dies when its solver reaches iteration `iter`.
    Kill { rank: usize, iter: usize },
    /// Rank `rank` stalls for `delay` at iteration `iter` (alive but slow
    /// — must *not* be mistaken for dead by a well-tuned timeout).
    Delay { rank: usize, iter: usize, delay: Duration },
}

/// A deterministic script of rank failures, keyed by (world rank,
/// solver iteration). Empty by default: no faults, zero overhead.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Script rank `rank` to die when its solve reaches iteration `iter`.
    pub fn kill(mut self, rank: usize, iter: usize) -> FaultPlan {
        self.faults.push(Fault::Kill { rank, iter });
        self
    }

    /// Script rank `rank` to stall for `delay` at iteration `iter`.
    pub fn delay(mut self, rank: usize, iter: usize, delay: Duration) -> FaultPlan {
        self.faults.push(Fault::Delay { rank, iter, delay });
        self
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Does the plan kill `rank` at exactly iteration `iter`? (A dead
    /// rank's thread is gone, so a match can only ever fire once.)
    pub fn kills_at(&self, rank: usize, iter: usize) -> bool {
        self.faults
            .iter()
            .any(|f| matches!(f, Fault::Kill { rank: r, iter: k } if *r == rank && *k == iter))
    }

    /// The scripted stall for `rank` at iteration `iter`, if any.
    pub fn delay_at(&self, rank: usize, iter: usize) -> Option<Duration> {
        self.faults.iter().find_map(|f| match f {
            Fault::Delay { rank: r, iter: k, delay } if *r == rank && *k == iter => Some(*delay),
            _ => None,
        })
    }
}

/// Recovery-event counters for one (possibly multi-attempt) solve.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// Rank failures agreed on by survivor consensus.
    pub detections: u64,
    /// Times the row partition was recomputed over a smaller world.
    pub resharding_rounds: u64,
    /// Checkpoint restores (a cold restart after a failure with no usable
    /// checkpoint does not count).
    pub restores: u64,
    /// Solver iterations discarded: progress past the last consistent
    /// checkpoint at the moment a failure was detected.
    pub wasted_iters: u64,
}

impl FaultReport {
    /// The quiet report: nothing failed, nothing recovered.
    pub fn none() -> FaultReport {
        FaultReport::default()
    }

    /// True when any recovery event was recorded.
    pub fn any(&self) -> bool {
        *self != FaultReport::default()
    }

    /// Accumulate another report (used by multiclass roll-up).
    pub fn merge(&mut self, other: &FaultReport) {
        self.detections += other.detections;
        self.resharding_rounds += other.resharding_rounds;
        self.restores += other.restores;
        self.wasted_iters += other.wasted_iters;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_failure_classifier_matches_only_dead_peer_signatures() {
        assert!(is_comm_failure(&Error::Cluster("rank 3 hung up".into())));
        assert!(is_comm_failure(&Error::Cluster(
            "rank 0: timeout waiting for (src=1, tag=7)".into()
        )));
        assert!(!is_comm_failure(&Error::Cluster("allreduce length mismatch".into())));
        assert!(!is_comm_failure(&Error::Data("spill x: bad magic".into())));
    }

    #[test]
    fn empty_plan_matches_nothing() {
        let plan = FaultPlan::new();
        assert!(plan.is_empty());
        assert!(!plan.kills_at(0, 0));
        assert_eq!(plan.delay_at(0, 0), None);
    }

    #[test]
    fn kill_matches_only_its_rank_and_iteration() {
        let plan = FaultPlan::new().kill(1, 40);
        assert!(plan.kills_at(1, 40));
        assert!(!plan.kills_at(1, 39));
        assert!(!plan.kills_at(1, 41));
        assert!(!plan.kills_at(0, 40));
        assert!(!plan.is_empty());
    }

    #[test]
    fn delay_reports_its_duration() {
        let plan = FaultPlan::new().delay(2, 7, Duration::from_millis(5));
        assert_eq!(plan.delay_at(2, 7), Some(Duration::from_millis(5)));
        assert_eq!(plan.delay_at(2, 8), None);
        assert!(!plan.kills_at(2, 7));
    }

    #[test]
    fn plans_compose_kills_and_delays() {
        let plan = FaultPlan::new().kill(3, 10).delay(1, 5, Duration::from_millis(1)).kill(2, 10);
        assert!(plan.kills_at(3, 10));
        assert!(plan.kills_at(2, 10));
        assert_eq!(plan.delay_at(1, 5), Some(Duration::from_millis(1)));
    }

    #[test]
    fn report_merge_sums_counters() {
        let mut a =
            FaultReport { detections: 1, resharding_rounds: 1, restores: 2, wasted_iters: 30 };
        let b = FaultReport { detections: 1, resharding_rounds: 0, restores: 1, wasted_iters: 12 };
        a.merge(&b);
        assert_eq!(
            a,
            FaultReport { detections: 2, resharding_rounds: 1, restores: 3, wasted_iters: 42 }
        );
        assert!(a.any());
        assert!(!FaultReport::none().any());
    }
}
